//! Pluggable runtime policies: routing ([`RoutePolicy`]), batch formation
//! ([`BatchPolicy`]) and offline temporal shifting ([`DeferralPolicy`]).
//!
//! Policies are the extension point for runtime-behaviour experiments: the
//! simulator core only ever talks to the traits, and the [`Router`] /
//! [`Batcher`] enums are thin config-level selectors over the shipped
//! impls. Custom policies plug in through [`crate::sim::simulate_with`].

use crate::carbon::intensity::CiSignal;
use crate::workload::RequestClass;

use super::carbon_meter::CarbonMeter;
use super::server::{ClassQueue, Job, Server};

/// Context a routing decision may consult: current time and the grid CI
/// each server currently sees (the cross-layer carbon signal).
pub struct RouteCtx<'a> {
    pub now: f64,
    pub(crate) meter: &'a CarbonMeter,
}

impl RouteCtx<'_> {
    /// Grid CI currently seen by `server`, gCO₂e/kWh.
    pub fn ci(&self, server: usize) -> f64 {
        self.meter.ci_at(server, self.now)
    }
}

/// Picks a server for an arriving request.
pub trait RoutePolicy: Send + Sync {
    fn name(&self) -> &'static str;
    /// Pick one of `eligible` (non-empty, all prompt-capable) for `job`.
    fn route(&self, job: &Job, servers: &[Server], eligible: &[usize],
             ctx: &RouteCtx) -> usize;
}

/// Forms prefill/decode batches from a server's queues. Implementations
/// *remove* the jobs they pick (O(batch) front pops on [`ClassQueue`] —
/// never a full-queue scan) and append them to `out`, a caller-owned
/// scratch buffer the core recycles across iterations so the hot path is
/// allocation-free. `jobs` is read-only context for policies that want
/// lengths or deadlines — it is the raw arena slot view, so only index
/// ids taken from the queue.
pub trait BatchPolicy: Send + Sync {
    fn name(&self) -> &'static str;
    /// Remove up to `max` job ids for the next prefill batch into `out`.
    fn select_prefill(&self, queue: &mut ClassQueue, jobs: &[Job], max: usize,
                      out: &mut Vec<usize>);
    /// Remove up to `max` job ids to admit into decode into `out`.
    fn select_decode(&self, queue: &mut ClassQueue, jobs: &[Job], max: usize,
                     out: &mut Vec<usize>);
}

/// Join-shortest-queue over eligible servers (Splitwise's policy).
pub struct Jsq;

impl RoutePolicy for Jsq {
    fn name(&self) -> &'static str {
        "jsq"
    }

    fn route(&self, _job: &Job, servers: &[Server], eligible: &[usize],
             _ctx: &RouteCtx) -> usize {
        *eligible.iter().min_by_key(|&&i| servers[i].depth()).unwrap()
    }
}

/// Workload-aware: long prompts to the largest-memory eligible pool, short
/// to the leanest; ties by queue depth (EcoServe's runtime component).
pub struct WorkloadAware;

/// Prompt length (tokens) at which a request counts as "long".
pub const LONG_PROMPT_TOKENS: usize = 1024;

impl RoutePolicy for WorkloadAware {
    fn name(&self) -> &'static str {
        "workload-aware"
    }

    fn route(&self, job: &Job, servers: &[Server], eligible: &[usize],
             _ctx: &RouteCtx) -> usize {
        let long = job.prompt >= LONG_PROMPT_TOKENS;
        *eligible.iter()
            .min_by(|&&a, &&b| {
                let (pa, da) = wa_key(&servers[a], long);
                let (pb, db) = wa_key(&servers[b], long);
                pa.total_cmp(&pb).then_with(|| da.cmp(&db)).then_with(|| a.cmp(&b))
            })
            .unwrap()
    }
}

fn wa_key(s: &Server, long: bool) -> (f64, usize) {
    let mem = s.spec().device.mem_gb;
    let pref = if long { -mem } else { mem };
    (pref, s.depth())
}

/// Carbon-greedy: prefer the eligible server whose grid currently has the
/// lowest CI, discounted by queue depth so a clean region saturating does
/// not starve latency forever (score = ci/mean_ci + queue_weight·depth).
pub struct CarbonGreedy {
    pub queue_weight: f64,
}

impl RoutePolicy for CarbonGreedy {
    fn name(&self) -> &'static str {
        "carbon-greedy"
    }

    fn route(&self, _job: &Job, servers: &[Server], eligible: &[usize],
             ctx: &RouteCtx) -> usize {
        let mean_ci = (eligible.iter().map(|&i| ctx.ci(i)).sum::<f64>()
            / eligible.len() as f64).max(1e-9);
        let score = |i: usize| -> f64 {
            ctx.ci(i) / mean_ci + self.queue_weight * servers[i].depth() as f64
        };
        *eligible.iter()
            .min_by(|&&a, &&b| {
                score(a).total_cmp(&score(b)).then_with(|| a.cmp(&b))
            })
            .unwrap()
    }
}

/// Plain FIFO batching: strict arrival order, blind to request class.
pub struct FifoBatch;

impl BatchPolicy for FifoBatch {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn select_prefill(&self, queue: &mut ClassQueue, _jobs: &[Job], max: usize,
                      out: &mut Vec<usize>) {
        queue.pop_fifo_into(max, out);
    }

    fn select_decode(&self, queue: &mut ClassQueue, _jobs: &[Job], max: usize,
                     out: &mut Vec<usize>) {
        queue.pop_fifo_into(max, out);
    }
}

/// Online-priority batching: interactive requests fill the batch first and
/// offline work pads the leftover slots, so deferred offline herds cannot
/// queue ahead of latency-sensitive traffic (EcoServe's runtime rule).
pub struct OnlineFirstBatch;

impl BatchPolicy for OnlineFirstBatch {
    fn name(&self) -> &'static str {
        "online-first"
    }

    fn select_prefill(&self, queue: &mut ClassQueue, _jobs: &[Job], max: usize,
                      out: &mut Vec<usize>) {
        queue.pop_online_first_into(max, out);
    }

    fn select_decode(&self, queue: &mut ClassQueue, _jobs: &[Job], max: usize,
                     out: &mut Vec<usize>) {
        queue.pop_online_first_into(max, out);
    }
}

static JSQ: Jsq = Jsq;
static WORKLOAD_AWARE: WorkloadAware = WorkloadAware;
static CARBON_GREEDY: CarbonGreedy = CarbonGreedy { queue_weight: 0.25 };
static FIFO: FifoBatch = FifoBatch;
static ONLINE_FIRST: OnlineFirstBatch = OnlineFirstBatch;

/// Config-level selector for the shipped routing policies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Router {
    /// Join-shortest-queue over eligible servers (Splitwise's policy).
    Jsq,
    /// Workload-aware: long prompts to high-memory servers (EcoServe).
    WorkloadAware,
    /// Lowest current grid CI, discounted by queue depth.
    CarbonGreedy,
}

impl Router {
    pub fn policy(&self) -> &'static dyn RoutePolicy {
        match self {
            Router::Jsq => &JSQ,
            Router::WorkloadAware => &WORKLOAD_AWARE,
            Router::CarbonGreedy => &CARBON_GREEDY,
        }
    }
}

/// Config-level selector for the shipped batch policies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Batcher {
    Fifo,
    OnlineFirst,
}

impl Batcher {
    pub fn policy(&self) -> &'static dyn BatchPolicy {
        match self {
            Batcher::Fifo => &FIFO,
            Batcher::OnlineFirst => &ONLINE_FIRST,
        }
    }
}

/// Fraction of an offline deadline usable as the release window (the rest
/// is service slack so deferred work still finishes on time).
const WINDOW_FRAC: f64 = 0.7;

/// Minimum CI improvement (gCO₂e/kWh) worth deferring for; guards against
/// chasing trace noise.
const MIN_WIN_G_PER_KWH: f64 = 1.0;

/// Temporal scheduling of offline-class requests (the paper's Reduce /
/// temporal-shifting lever). Online work is never deferred.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DeferralPolicy {
    /// Route offline work the moment it arrives.
    Immediate,
    /// Shift each offline request to the lowest-CI point of
    /// `[arrival, min(arrival + 0.7·deadline_s, horizon_s)]`, spacing
    /// releases `spacing_s` apart so the low-CI window does not turn into
    /// a thundering herd. A request is only deferred when the realized
    /// release slot still beats running immediately.
    LowCiWindow {
        deadline_s: f64,
        spacing_s: f64,
        /// Don't release past this point (normally the trace duration), so
        /// shifting never stretches the accounted sim horizon.
        horizon_s: f64,
    },
}

impl DeferralPolicy {
    /// Completion deadline for a request under this policy.
    pub(crate) fn deadline_for(&self, class: RequestClass, arrival_s: f64) -> f64 {
        match self {
            DeferralPolicy::LowCiWindow { deadline_s, .. }
                if class == RequestClass::Offline => arrival_s + deadline_s,
            _ => f64::INFINITY,
        }
    }
}

/// Runtime state of the deferral queue (release-slot spacing).
#[derive(Debug)]
pub(crate) struct DeferState {
    policy: DeferralPolicy,
    next_slot: f64,
}

impl DeferState {
    pub fn new(policy: DeferralPolicy) -> DeferState {
        DeferState { policy, next_slot: 0.0 }
    }

    /// Release time for an offline request arriving at `now`, or `None`
    /// to run it immediately. Deterministic: scans the CI signal at trace
    /// resolution, ties break to the earliest slot.
    pub fn release_time(&mut self, now: f64, signal: &CiSignal) -> Option<f64> {
        let DeferralPolicy::LowCiWindow { deadline_s, spacing_s, horizon_s } =
            self.policy
        else {
            return None;
        };
        let step = signal.step_s()?; // flat signal: nothing to gain
        let cap = (now + WINDOW_FRAC * deadline_s).min(horizon_s);
        if cap <= now {
            return None;
        }
        let now_ci = signal.at(now);
        let mut best_t = now;
        let mut best_ci = now_ci;
        let mut t = now + step;
        while t <= cap {
            let ci = signal.at(t);
            if ci + 1e-9 < best_ci {
                best_ci = ci;
                best_t = t;
            }
            t += step;
        }
        if best_t <= now {
            return None;
        }
        // Serialize releases; only defer if the realized slot still wins.
        let release = best_t.max(self.next_slot);
        if release > cap || signal.at(release) + MIN_WIN_G_PER_KWH >= now_ci {
            return None;
        }
        self.next_slot = release + spacing_s;
        Some(release)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::carbon::intensity::{CiTrace, Region};
    use crate::models;
    use crate::sim::core::SimConfig;
    use crate::sim::server::homogeneous_fleet;
    use crate::testkit::{forall, PropConfig};
    use crate::util::rng::Rng;

    fn job(prompt: usize) -> Job {
        Job {
            arrival: 0.0,
            prompt,
            output: 16,
            class: RequestClass::Online,
            slo_ttft: 0.5,
            slo_tpot: 0.1,
            deadline: f64::INFINITY,
            dispatched_t: 0.0,
            first_token_t: None,
            decoded: 0,
        }
    }

    /// Build runtime servers with the given (prompt_q, active) depths.
    fn servers_with_depths(specs: &[super::super::server::ServerSpec],
                           depths: &[(usize, usize)]) -> Vec<Server> {
        specs.iter().zip(depths).map(|(spec, &(q, a))| {
            let mut s = Server::new(spec);
            for i in 0..q {
                s.prompt_q.push(i, RequestClass::Online);
            }
            for i in 0..a {
                s.active.push(i);
            }
            s
        }).collect()
    }

    fn flat_ctx_cfg(n: usize) -> SimConfig {
        let m = models::llm("llama-8b").unwrap();
        let fleet = homogeneous_fleet("A100-40", n, m, 2048);
        SimConfig::flat(fleet, Router::Jsq, 261.0, vec![0.005; n])
    }

    #[test]
    fn prop_jsq_never_routes_to_a_strictly_longer_queue() {
        let specs = {
            let m = models::llm("llama-8b").unwrap();
            homogeneous_fleet("A100-40", 6, m, 2048)
        };
        let cfg = flat_ctx_cfg(6);
        let meter = CarbonMeter::new(&cfg);
        forall(
            &PropConfig { cases: 200, ..Default::default() },
            |r: &mut Rng| {
                let n = 2 + r.below(5);
                (0..n).map(|_| (r.below(10), r.below(8))).collect::<Vec<_>>()
            },
            |_| Vec::new(),
            |depths| {
                let servers = servers_with_depths(&specs[..depths.len()], depths);
                let eligible: Vec<usize> = (0..depths.len()).collect();
                let ctx = RouteCtx { now: 0.0, meter: &meter };
                let sid = Jsq.route(&job(256), &servers, &eligible, &ctx);
                let chosen = servers[sid].depth();
                for &i in &eligible {
                    if servers[i].depth() < chosen {
                        return Err(format!(
                            "routed to depth {chosen} with server {i} at {}",
                            servers[i].depth()));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_workload_aware_sends_long_prompts_to_largest_memory() {
        // Heterogeneous pool: A100-80 (80 GB) + A100-40 + L4.
        let m = models::llm("llama-8b").unwrap();
        let mut specs = homogeneous_fleet("A100-80", 1, m, 2048);
        specs.extend(homogeneous_fleet("A100-40", 1, m, 2048));
        specs.extend(homogeneous_fleet("L4", 1, m, 2048));
        let cfg = flat_ctx_cfg(3);
        let meter = CarbonMeter::new(&cfg);
        let max_mem = specs.iter().map(|s| s.device.mem_gb)
            .fold(f64::MIN, f64::max);
        forall(
            &PropConfig { cases: 200, ..Default::default() },
            |r: &mut Rng| {
                let depths: Vec<(usize, usize)> =
                    (0..3).map(|_| (r.below(10), r.below(8))).collect();
                let prompt = LONG_PROMPT_TOKENS + r.below(8192);
                (depths, prompt)
            },
            |_| Vec::new(),
            |(depths, prompt)| {
                let servers = servers_with_depths(&specs, depths);
                let eligible = vec![0, 1, 2];
                let ctx = RouteCtx { now: 0.0, meter: &meter };
                let sid = WorkloadAware.route(&job(*prompt), &servers,
                                              &eligible, &ctx);
                let mem = servers[sid].spec().device.mem_gb;
                if mem < max_mem {
                    return Err(format!(
                        "long prompt ({prompt} tok) routed to {mem} GB, max {max_mem}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn carbon_greedy_prefers_clean_grid_until_queues_pile_up() {
        let m = models::llm("llama-8b").unwrap();
        let mut specs = homogeneous_fleet("A100-40", 2, m, 2048);
        specs[0].region = Some(Region::SwedenNorth); // 17 g/kWh
        specs[1].region = Some(Region::Midcontinent); // 501 g/kWh
        let cfg = SimConfig::flat(specs.clone(), Router::CarbonGreedy, 261.0,
                                  vec![0.005; 2]);
        let meter = CarbonMeter::new(&cfg);
        let ctx = RouteCtx { now: 0.0, meter: &meter };
        let empty = servers_with_depths(&specs, &[(0, 0), (0, 0)]);
        assert_eq!(CARBON_GREEDY.route(&job(256), &empty, &[0, 1], &ctx), 0);
        // A deep enough clean-grid queue finally spills to the dirty grid.
        let deep = servers_with_depths(&specs, &[(40, 20), (0, 0)]);
        assert_eq!(CARBON_GREEDY.route(&job(256), &deep, &[0, 1], &ctx), 1);
    }

    #[test]
    fn online_first_batch_pads_with_offline() {
        let mut jobs: Vec<Job> = (0..6).map(|_| job(128)).collect();
        jobs[1].class = RequestClass::Offline;
        jobs[2].class = RequestClass::Offline;
        let fill = |jobs: &[Job]| {
            let mut q = ClassQueue::default();
            for (j, jb) in jobs.iter().enumerate() {
                q.push(j, jb.class);
            }
            q
        };
        let select = |policy: &dyn BatchPolicy, q: &mut ClassQueue, max| {
            let mut out = Vec::new();
            policy.select_prefill(q, &jobs, max, &mut out);
            out
        };
        // Online 0,3,4,5 fill the batch before offline 1,2 get a slot.
        let mut q = fill(&jobs);
        assert_eq!(select(&OnlineFirstBatch, &mut q, 4), vec![0, 3, 4, 5]);
        assert_eq!(q.len(), 2, "unpicked jobs stay queued");
        let mut q = fill(&jobs);
        assert_eq!(select(&OnlineFirstBatch, &mut q, 5), vec![0, 3, 4, 5, 1]);
        // Strict FIFO is blind to class.
        let mut q = fill(&jobs);
        assert_eq!(select(&FifoBatch, &mut q, 4), vec![0, 1, 2, 3]);
    }

    #[test]
    fn deferral_targets_the_dip_and_respects_caps() {
        let tr = CiTrace::compressed_diurnal(Region::California, 240.0, 2, 96, 7);
        let sig = CiSignal::Trace(tr);
        let policy = DeferralPolicy::LowCiWindow {
            deadline_s: 192.0,
            spacing_s: 0.5,
            horizon_s: 240.0,
        };
        let mut st = DeferState::new(policy);
        // Early-morning arrival defers into the solar dip (~13/24 of 240 s).
        let r = st.release_time(10.0, &sig).expect("should defer");
        assert!(r > 10.0 && r <= 10.0 + 0.7 * 192.0);
        assert!(sig.at(r) < sig.at(10.0), "release CI {} >= now CI {}",
                sig.at(r), sig.at(10.0));
        // Spacing: the next release never lands before the previous + gap.
        let r2 = st.release_time(10.5, &sig).expect("should defer");
        assert!(r2 >= r + 0.5 - 1e-9, "r2 {r2} vs r {r}");
        // Flat signal: never defers.
        let mut st2 = DeferState::new(policy);
        assert!(st2.release_time(10.0, &CiSignal::flat(261.0)).is_none());
        // Immediate policy: never defers.
        let mut st3 = DeferState::new(DeferralPolicy::Immediate);
        assert!(st3.release_time(10.0, &sig).is_none());
    }

    #[test]
    fn workload_aware_router_helps_mixed_lengths() {
        use crate::sim::simulate;
        use crate::workload::{generate_trace, Arrivals, LengthDist};
        let m = models::llm("gemma-27b").unwrap();
        // Heterogeneous fleet: one big-memory A100-80, one lean A100-40.
        let mut servers = homogeneous_fleet("A100-80", 1, m, 2048);
        servers.extend(homogeneous_fleet("A100-40", 1, m, 2048));
        let tr = generate_trace(Arrivals::Poisson { rate: 1.0 },
                                LengthDist::AzureCode, RequestClass::Online,
                                240.0, 5);
        let n = servers.len();
        let mk = |router: Router| {
            let cfg = SimConfig::flat(servers.clone(), router, 261.0,
                                      vec![0.005; n]);
            simulate(m, &tr, &cfg, 10.0, 0.2)
        };
        let jsq = mk(Router::Jsq);
        let wa = mk(Router::WorkloadAware);
        // Workload-aware must not be worse on p90 TTFT (usually better).
        assert!(wa.ttft.p90() <= jsq.ttft.p90() * 1.35,
                "wa {} jsq {}", wa.ttft.p90(), jsq.ttft.p90());
    }

    #[test]
    fn deadlines_only_for_offline_under_deferral() {
        let p = DeferralPolicy::LowCiWindow {
            deadline_s: 100.0, spacing_s: 0.5, horizon_s: 200.0,
        };
        assert_eq!(p.deadline_for(RequestClass::Offline, 5.0), 105.0);
        assert!(p.deadline_for(RequestClass::Online, 5.0).is_infinite());
        assert!(DeferralPolicy::Immediate
            .deadline_for(RequestClass::Offline, 5.0).is_infinite());
    }
}
