//! Rolling-horizon re-provisioning: the controller that closes the loop
//! between the allocation ILP and the cluster simulator (the paper's
//! periodic pool management, §4.2.2's planner run "at every epoch").
//!
//! At every epoch boundary the controller looks at the demand *observed*
//! over the trailing window (it is causal: nothing ahead of the boundary
//! is visible), re-solves the allocation ILP restricted to the SKUs of
//! the provisioned template fleet with the CI-signal forecast for the
//! next epoch as the planning carbon intensity, and converts the solved
//! fleet into [`FleetSchedule`] provisioning events: servers the new plan
//! no longer needs are drained (they finish in-flight batches, then
//! decommission), previously drained servers are re-provisioned when
//! demand returns (the 4R "Recycle" of still-amortizing hardware).
//!
//! Embodied carbon is charged per provisioned-hour in the simulator, so a
//! right-sized elastic fleet is *visibly* cheaper in total kgCO₂e than a
//! static peak-provisioned one — the cross-stack claim this module exists
//! to reproduce.

use crate::carbon::intensity::CiSignal;
use crate::models::LlmSpec;
use crate::planner::benders;
use crate::planner::fused::{DemandProfile, PeakGrid};
use crate::planner::slicing::{cluster_slices, Slice, SliceAccum};
use crate::planner::{self, Plan, PlanConfig, WarmStart};
use crate::sim::{FleetAction, FleetEvent, FleetSchedule, Role, ServerSpec};
use crate::workload::slo::Slo;
use crate::workload::{ArrivalSource, Request, SliceSource};
use std::collections::BTreeMap;

/// Controller knobs. All durations are simulated seconds (a compressed
/// trace maps "every 15 real minutes" onto its own time scale).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HorizonConfig {
    /// Re-plan period. Clamped at run time to `[duration/96, duration/2]`
    /// so a schedule always has between 1 and 95 re-plan boundaries.
    pub epoch_s: f64,
    /// Demand observation window; `0` means one epoch.
    pub window_s: f64,
    /// Capacity margin over observed demand (provisioning for the mean of
    /// a window invites SLO misses on its peaks).
    pub headroom: f64,
    /// Never drain the fleet below this many active servers.
    pub min_active: usize,
    /// Branch-and-bound node budget per epoch solve (node-bound, never
    /// wall-clock-bound, to keep schedules deterministic).
    pub milp_nodes: usize,
    /// Reuse the previous epoch's plan when the demand histogram moved by
    /// at most this fraction (relative L1 over bucket counts, and the
    /// planning CI within the same fraction). At the default `0.0`, reuse
    /// happens only on *bitwise-identical* inputs, which is output-neutral
    /// by construction — nonzero tolerances trade plan freshness for
    /// re-solve count and legitimately change schedules.
    pub drift_tol: f64,
    /// Patch demand growth with Benders-style interval capacity cuts
    /// instead of full re-solves (see [`crate::planner::benders`]). A
    /// modeling shortcut, off by default to keep schedules bitwise-stable.
    pub interval_cuts: bool,
}

impl Default for HorizonConfig {
    fn default() -> Self {
        HorizonConfig {
            epoch_s: 15.0,
            window_s: 0.0,
            headroom: 1.3,
            min_active: 1,
            milp_nodes: 200,
            drift_tol: 0.0,
            interval_cuts: false,
        }
    }
}

impl HorizonConfig {
    /// The epoch actually used against a trace of `duration_s` seconds.
    pub fn effective_epoch(&self, duration_s: f64) -> f64 {
        assert!(self.epoch_s > 0.0 && duration_s > 0.0,
                "epoch and duration must be positive");
        self.epoch_s.clamp(duration_s / 96.0, duration_s / 2.0)
    }
}

/// The busiest epoch-sized demand window over an arrival stream, found in
/// one pass and O(windows) memory: windows slide at quarter-epoch steps
/// (so a burst straddling an epoch-aligned boundary is not undercounted)
/// and the first strictly-maximal window wins. Returns the window's
/// `(t_lo, t_hi, count)`; `count == 0` means the stream was empty.
pub fn peak_window_over(source: &mut dyn ArrivalSource, epoch_s: f64,
                        duration_s: f64) -> (f64, f64, usize) {
    // One shared PeakGrid implementation (`planner::fused`) backs this
    // scan, the materialized adapter below, and the fused DemandProfile —
    // the three paths cannot disagree, on ties or otherwise.
    let mut grid = PeakGrid::new(epoch_s, duration_s);
    while let Some(r) = source.next_request() {
        grid.observe(r.arrival_s, |_| {});
    }
    grid.best()
}

/// Index range (into an arrival-sorted trace) of the busiest epoch-sized
/// window — what "peak-provisioned" means for the static baseline and for
/// sizing the elastic template fleet. Materialized adapter over
/// [`peak_window_over`]; `(0, len)` when the trace is empty.
pub fn peak_epoch_window(trace: &[Request], epoch_s: f64, duration_s: f64)
    -> (usize, usize) {
    let (t_lo, t_hi, n) = peak_window_over(&mut SliceSource::new(trace),
                                           epoch_s, duration_s);
    if n == 0 {
        return (0, trace.len());
    }
    let lo = trace.partition_point(|r| r.arrival_s < t_lo);
    let hi = trace.partition_point(|r| r.arrival_s < t_hi);
    (lo, hi)
}

/// Build the provisioning schedule for `template` over a materialized
/// trace — a thin adapter over [`plan_schedule_stream`].
#[allow(clippy::too_many_arguments)]
pub fn plan_schedule(model: &'static LlmSpec, trace: &[Request],
                     template: &[ServerSpec], base: &PlanConfig,
                     ci: &CiSignal, slo: Slo, h: &HorizonConfig,
                     duration_s: f64) -> FleetSchedule {
    plan_schedule_stream(model, &mut SliceSource::new(trace), template, base,
                         ci, slo, h, duration_s)
}

/// Cross-epoch incremental state of the rolling-horizon controller: the
/// previous epoch's demand histogram and solved plan, plus counters for
/// what each epoch cost.
///
/// Decision ladder per epoch (first match wins):
/// 1. **warm hit** — bitwise-identical `(histogram, window, ci)`: return
///    the cached plan. [`planner::plan`] is pure in its inputs, so this is
///    exact memoization, on by default and output-neutral.
/// 2. **drift skip** — inputs moved, but by at most `drift_tol`: return
///    the cached plan anyway. Drift is measured against the demand the
///    plan was last (re)solved for — never against the previous skip — so
///    slow creep accumulates until it trips the threshold instead of
///    being re-absorbed forever.
/// 3. **cut patch** (`interval_cuts`) — demand grew without opening new
///    buckets: sweep the epoch's chunk events for overload intervals and
///    patch the cached plan with per-interval capacity cuts
///    ([`benders::patch_plan`]); re-anchor on the patched plan.
/// 4. **full re-solve** — anything else (including any demand *shrink*:
///    cuts only add capacity, scale-down needs the real ILP).
pub struct IncrementalPlanner {
    drift_tol: f64,
    cuts: bool,
    /// `false` disables every reuse path — the cold from-scratch baseline
    /// `plan-bench` compares against.
    enabled: bool,
    last: Option<EpochSolve>,
    stats: PlannerStats,
}

struct EpochSolve {
    acc: SliceAccum,
    w_bits: u64,
    warm: WarmStart,
}

/// Where the controller's epochs went — the sublinearity evidence
/// `plan-bench` reports.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PlannerStats {
    pub epochs: usize,
    pub full_solves: usize,
    /// Exact-match memoization hits.
    pub warm_hits: usize,
    /// Within-tolerance reuses of a drifted histogram.
    pub drift_skips: usize,
    /// Epochs resolved by patching the master with interval cuts.
    pub cut_patches: usize,
    /// Per-interval feasibility subproblems solved.
    pub cuts: usize,
    /// Branch-and-bound nodes across full solves and cut subproblems.
    pub nodes: usize,
}

impl PlannerStats {
    /// Elementwise accumulate (shard observers fold their counters back
    /// into one fleet-level total; `usize` sums commute, so the result is
    /// thread-invariant).
    pub fn absorb(&mut self, o: PlannerStats) {
        self.epochs += o.epochs;
        self.full_solves += o.full_solves;
        self.warm_hits += o.warm_hits;
        self.drift_skips += o.drift_skips;
        self.cut_patches += o.cut_patches;
        self.cuts += o.cuts;
        self.nodes += o.nodes;
    }
}

impl IncrementalPlanner {
    pub fn new(drift_tol: f64, interval_cuts: bool) -> IncrementalPlanner {
        IncrementalPlanner {
            drift_tol,
            cuts: interval_cuts,
            enabled: true,
            last: None,
            stats: PlannerStats::default(),
        }
    }

    /// Planner configured from the horizon knobs (what
    /// [`plan_schedule_stream`] runs).
    pub fn from_horizon(h: &HorizonConfig) -> IncrementalPlanner {
        IncrementalPlanner::new(h.drift_tol, h.interval_cuts)
    }

    /// Every epoch re-solves from scratch: the cold baseline.
    pub fn disabled() -> IncrementalPlanner {
        let mut p = IncrementalPlanner::new(0.0, false);
        p.enabled = false;
        p
    }

    pub fn stats(&self) -> PlannerStats {
        self.stats
    }

    /// The observed-demand slices of one epoch window, headroom-scaled —
    /// exactly what the per-epoch ILP solves over.
    fn window_slices(acc: &SliceAccum, model: &'static LlmSpec, w: f64,
                     slo: Slo, headroom: f64) -> Vec<Slice> {
        let mut slices = cluster_slices(&acc.slices(model, w, slo, 1));
        for s in &mut slices {
            s.rate *= headroom;
        }
        slices
    }

    /// Plan schedule epoch `k` (1-based) of `profile`. `cfg` must already
    /// carry this epoch's CI forecast; every other field must be held
    /// constant across the planner's lifetime.
    pub fn epoch_plan(&mut self, profile: &DemandProfile, k: usize,
                      cfg: &PlanConfig, model: &'static LlmSpec, slo: Slo,
                      h: &HorizonConfig) -> Plan {
        self.stats.epochs += 1;
        let t_k = k as f64 * profile.epoch_s;
        let w = profile.window_s.min(t_k);
        let acc = profile.epoch_accum(k);

        if !self.enabled {
            let slices = Self::window_slices(acc, model, w, slo, h.headroom);
            let p = planner::plan(&slices, cfg);
            self.stats.full_solves += 1;
            self.stats.nodes += p.nodes;
            return p;
        }

        if let Some(last) = &self.last {
            let same_w = last.w_bits == w.to_bits();
            // 1. Exact memoization: bitwise-identical inputs.
            if same_w && last.warm.ci.to_bits() == cfg.ci.to_bits()
                && last.acc == *acc {
                self.stats.warm_hits += 1;
                let mut p = last.warm.plan.clone();
                p.solve_s = 0.0;
                p.nodes = 0;
                return p;
            }
            // 2. Delta-aware early-out: within tolerance of the demand the
            // plan was last solved/patched for.
            if self.drift_tol > 0.0 && same_w {
                let denom = last.acc.total().max(acc.total()).max(1) as f64;
                let drift_hist = last.acc.l1_delta(acc) as f64 / denom;
                let drift_ci = (cfg.ci - last.warm.ci).abs()
                    / last.warm.ci.abs().max(1e-9);
                if drift_hist <= self.drift_tol && drift_ci <= self.drift_tol {
                    self.stats.drift_skips += 1;
                    let mut p = last.warm.plan.clone();
                    p.solve_s = 0.0;
                    p.nodes = 0;
                    return p;
                }
            }
            // 3. Interval cuts: growth the master's columns can absorb.
            if self.cuts && same_w && acc.total() >= last.acc.total()
                && !last.acc.has_new_bucket(acc) {
                let q = profile.epoch_s / 4.0;
                let chunks = profile.chunk_rates(t_k - w, t_k);
                if let Some(out) = benders::patch_plan(&last.warm, cfg,
                                                       &chunks, q, h.headroom) {
                    self.stats.cut_patches += 1;
                    self.stats.cuts += out.cuts;
                    self.stats.nodes += out.nodes;
                    let slices =
                        Self::window_slices(acc, model, w, slo, h.headroom);
                    let plan = out.plan.clone();
                    self.last = Some(EpochSolve {
                        acc: acc.clone(),
                        w_bits: w.to_bits(),
                        warm: WarmStart { slices, ci: cfg.ci, plan: out.plan },
                    });
                    return plan;
                }
            }
        }

        // 4. Full re-solve; re-anchor the incremental state on it.
        let slices = Self::window_slices(acc, model, w, slo, h.headroom);
        let p = planner::plan_warm(&slices, cfg,
                                   self.last.as_ref().map(|l| &l.warm));
        self.stats.full_solves += 1;
        self.stats.nodes += p.nodes;
        self.last = Some(EpochSolve {
            acc: acc.clone(),
            w_bits: w.to_bits(),
            warm: WarmStart::new(&slices, cfg, p.clone()),
        });
        p
    }
}

/// Build the provisioning schedule for `template` over a streaming
/// arrival source.
///
/// The template is the peak-provisioned fleet (every server the schedule
/// may ever use); the whole template starts active, and from the first
/// epoch boundary on, the observed-demand ILP decides how much of it
/// stays up. One fused pass over the stream builds the demand profile
/// (O(windows × buckets) memory — never the whole trace), then the
/// incremental planner walks the epochs. Deterministic: same inputs, same
/// schedule, independent of thread count (the per-epoch MILP is
/// node-bounded).
#[allow(clippy::too_many_arguments)]
pub fn plan_schedule_stream(model: &'static LlmSpec,
                            source: &mut dyn ArrivalSource,
                            template: &[ServerSpec], base: &PlanConfig,
                            ci: &CiSignal, slo: Slo, h: &HorizonConfig,
                            duration_s: f64) -> FleetSchedule {
    plan_schedule_stream_with_stats(model, source, template, base, ci, slo,
                                    h, duration_s).0
}

/// [`plan_schedule_stream`] that also hands back the incremental
/// planner's decision-ladder counters ([`PlannerStats`]) — what the
/// observability layer's self-profile records per scenario run. The
/// schedule bytes are identical to [`plan_schedule_stream`]; the stats
/// are a passive read of the planner it ran anyway.
#[allow(clippy::too_many_arguments)]
pub fn plan_schedule_stream_with_stats(model: &'static LlmSpec,
                                       source: &mut dyn ArrivalSource,
                                       template: &[ServerSpec],
                                       base: &PlanConfig, ci: &CiSignal,
                                       slo: Slo, h: &HorizonConfig,
                                       duration_s: f64)
    -> (FleetSchedule, PlannerStats) {
    let epoch = h.effective_epoch(duration_s);
    let profile = DemandProfile::build(source, epoch, h.window_s, duration_s);
    let mut inc = IncrementalPlanner::from_horizon(h);
    let schedule = plan_schedule_from_profile(model, &profile, template, base,
                                              ci, slo, h, duration_s, &mut inc);
    (schedule, inc.stats())
}

/// The epoch loop of [`plan_schedule_stream`], decoupled from the demand
/// walk: plan every schedule epoch of an already-built [`DemandProfile`]
/// through `inc`. `plan-bench` drives this directly to compare cold and
/// warm planners over one shared profile.
#[allow(clippy::too_many_arguments)]
pub fn plan_schedule_from_profile(model: &'static LlmSpec,
                                  profile: &DemandProfile,
                                  template: &[ServerSpec], base: &PlanConfig,
                                  ci: &CiSignal, slo: Slo, h: &HorizonConfig,
                                  duration_s: f64,
                                  inc: &mut IncrementalPlanner)
    -> FleetSchedule {
    assert!(!template.is_empty(), "empty template fleet");
    let epoch = h.effective_epoch(duration_s);
    assert_eq!(profile.epoch_s.to_bits(), epoch.to_bits(),
               "profile built for a different epoch");
    let window = if h.window_s > 0.0 { h.window_s } else { epoch };
    assert_eq!(profile.window_s.to_bits(), window.to_bits(),
               "profile built for a different observation window");

    // Template servers grouped by SKU (BTreeMap: deterministic order).
    // Within a group, low indices activate first and high indices drain
    // first, so server identity is stable across epochs.
    let mut groups: BTreeMap<&'static str, Vec<usize>> = BTreeMap::new();
    for (i, s) in template.iter().enumerate() {
        if let Some(g) = crate::hw::gpu(&s.device.name) {
            groups.entry(g.name).or_default().push(i);
        }
    }
    assert!(!groups.is_empty(), "template has no catalog GPUs");
    let menu: Vec<&'static str> = groups.keys().copied().collect();

    // Per-epoch solve config; only `ci` varies inside the loop (the
    // incremental planner's warm-start contract).
    let mut cfg = base.clone();
    cfg.gpu_menu = menu.clone();
    cfg.milp.max_nodes = h.milp_nodes;
    cfg.milp.time_limit = std::time::Duration::from_secs(3600);

    let mut active: Vec<bool> = vec![true; template.len()];
    let mut events = Vec::new();
    for k in 1..=profile.epochs() {
        let t_k = k as f64 * epoch;

        let mut desired: BTreeMap<&'static str, usize> =
            menu.iter().map(|n| (*n, 0)).collect();
        if profile.epoch_accum(k).total() > 0 {
            // CI forecast for the next epoch: the planning carbon price.
            cfg.ci = ci.mean_over(t_k, (t_k + epoch).min(duration_s));
            let plan = inc.epoch_plan(profile, k, &cfg, model, slo, h);
            for (name, &gpus) in &plan.counts {
                let Some((sku, idxs)) = groups.get_key_value(name.as_str()) else {
                    continue; // cpu-host reuse consumes no template server
                };
                let tp = template[idxs[0]].tp.max(1);
                desired.insert(*sku, gpus.div_ceil(tp).min(idxs.len()));
            }
        }

        // Desired active set: the first `n` servers of each SKU group.
        let mut want = vec![false; template.len()];
        for (name, idxs) in &groups {
            let n = desired.get(name).copied().unwrap_or(0);
            for &i in idxs.iter().take(n) {
                want[i] = true;
            }
        }
        // Floors: total active count, and at least one prompt-capable
        // server so the routing invariant can never be violated.
        let floor = h.min_active.max(1);
        let mut n_active = want.iter().filter(|w| **w).count();
        for w in want.iter_mut() {
            if n_active >= floor {
                break;
            }
            if !*w {
                *w = true;
                n_active += 1;
            }
        }
        if !want.iter().zip(template).any(|(w, s)| *w && s.role != Role::Decode) {
            let i = template.iter().position(|s| s.role != Role::Decode)
                .expect("template has no prompt-capable server");
            want[i] = true;
        }
        // Symmetric guard for disaggregated templates: prefill handoffs
        // need a decode-capable server too, or decode batches would fall
        // back onto prompt-role hardware.
        if !want.iter().zip(template).any(|(w, s)| *w && s.role != Role::Prompt) {
            if let Some(i) = template.iter().position(|s| s.role != Role::Prompt) {
                want[i] = true;
            }
        }

        // Diff against the running fleet → provisioning events.
        for i in 0..template.len() {
            if want[i] && !active[i] {
                events.push(FleetEvent {
                    t: t_k, server: i, action: FleetAction::Provision,
                });
            } else if !want[i] && active[i] {
                events.push(FleetEvent {
                    t: t_k, server: i, action: FleetAction::Drain,
                });
            }
        }
        active = want;
    }
    FleetSchedule { initially_active: Vec::new(), events }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::sim::homogeneous_fleet;
    use crate::workload::{generate_trace, Arrivals, LengthDist, RequestClass};

    fn diurnal_trace(duration_s: f64, seed: u64) -> Vec<Request> {
        generate_trace(
            Arrivals::CompressedDiurnal { rate: 10.0, amplitude: 0.7, period_s: 0.0 },
            LengthDist::ShareGpt, RequestClass::Online, duration_s, seed)
    }

    fn controller_inputs() -> (&'static LlmSpec, Vec<ServerSpec>, PlanConfig, Slo) {
        let m = models::llm("llama-8b").unwrap();
        let template = homogeneous_fleet("A100-40", 6, m, 2048);
        let cfg = PlanConfig { cpu_reuse: false, ..Default::default() };
        (m, template, cfg, Slo { ttft_s: 2.0, tpot_s: 0.2 })
    }

    /// Replay a schedule and return the active-server count over time.
    fn replay(template_len: usize, sched: &FleetSchedule) -> Vec<(f64, usize)> {
        let mut active = vec![true; template_len];
        if !sched.initially_active.is_empty() {
            active = sched.initially_active.clone();
        }
        let mut out = vec![(0.0, active.iter().filter(|a| **a).count())];
        for e in &sched.events {
            active[e.server] = e.action == FleetAction::Provision;
            out.push((e.t, active.iter().filter(|a| **a).count()));
        }
        out
    }

    #[test]
    fn peak_window_finds_the_surge() {
        let tr = generate_trace(
            Arrivals::Step { base: 1.0, surge: 20.0, start_frac: 0.5, end_frac: 0.7 },
            LengthDist::ShareGpt, RequestClass::Online, 200.0, 3);
        let (lo, hi) = peak_epoch_window(&tr, 20.0, 200.0);
        assert!(hi > lo);
        // The densest 20 s window lies inside the surge [100, 140).
        assert!(tr[lo].arrival_s >= 100.0 - 1e-9 && tr[hi - 1].arrival_s < 140.0,
                "peak window [{}, {})", tr[lo].arrival_s, tr[hi - 1].arrival_s);
    }

    #[test]
    fn schedule_is_deterministic_and_time_ordered() {
        let (m, template, cfg, slo) = controller_inputs();
        let tr = diurnal_trace(240.0, 11);
        let h = HorizonConfig::default();
        let ci = CiSignal::flat(261.0);
        let a = plan_schedule(m, &tr, &template, &cfg, &ci, slo, &h, 240.0);
        let b = plan_schedule(m, &tr, &template, &cfg, &ci, slo, &h, 240.0);
        assert_eq!(a, b, "same inputs must give the same schedule");
        assert!(a.events.windows(2).all(|w| w[0].t <= w[1].t));
    }

    #[test]
    fn diurnal_demand_scales_the_fleet_down_and_back() {
        let (m, template, cfg, slo) = controller_inputs();
        let tr = diurnal_trace(240.0, 12);
        let h = HorizonConfig { epoch_s: 20.0, ..Default::default() };
        let ci = CiSignal::flat(261.0);
        let sched = plan_schedule(m, &tr, &template, &cfg, &ci, slo, &h, 240.0);
        assert!(sched.events.iter().any(|e| e.action == FleetAction::Drain),
                "a 0.7-amplitude diurnal load should shed servers off-peak");
        let counts = replay(template.len(), &sched);
        let min = counts.iter().map(|(_, n)| *n).min().unwrap();
        let max = counts.iter().map(|(_, n)| *n).max().unwrap();
        assert!(min < max, "fleet never resized: min {min} max {max}");
    }

    #[test]
    fn floor_is_never_violated() {
        let (m, template, cfg, slo) = controller_inputs();
        // Nearly idle trace: without the floor the ILP would drain to 0.
        let tr = generate_trace(Arrivals::Poisson { rate: 0.02 },
                                LengthDist::ShareGpt, RequestClass::Online,
                                240.0, 13);
        let h = HorizonConfig { min_active: 2, ..Default::default() };
        let ci = CiSignal::flat(261.0);
        let sched = plan_schedule(m, &tr, &template, &cfg, &ci, slo, &h, 240.0);
        for (t, n) in replay(template.len(), &sched) {
            assert!(n >= 2, "active fleet fell to {n} at t={t}");
        }
    }

    /// One arrival per second at a fixed length: dozens of equally-busy
    /// windows. Regression for the tie-break contract — the *first*
    /// strictly-maximal window wins, identically across the streaming
    /// scan, the materialized adapter, and the fused profile.
    #[test]
    fn peak_tie_break_is_first_strict_max_on_plateau() {
        use crate::planner::fused::DemandProfile;
        use crate::workload::RequestClass;
        let tr: Vec<Request> = (0..200)
            .map(|i| Request {
                id: i,
                arrival_s: i as f64 + 0.5,
                prompt_tokens: 256,
                output_tokens: 128,
                class: RequestClass::Online,
            })
            .collect();
        let (t_lo, t_hi, n) =
            peak_window_over(&mut SliceSource::new(&tr), 20.0, 200.0);
        // Every interior 20 s window holds exactly 20 arrivals; the
        // earliest must win the tie.
        assert_eq!((t_lo.to_bits(), t_hi.to_bits(), n),
                   (0.0f64.to_bits(), 20.0f64.to_bits(), 20));
        let (lo, hi) = peak_epoch_window(&tr, 20.0, 200.0);
        assert_eq!((lo, hi), (0, 20));
        let p = DemandProfile::build(&mut SliceSource::new(&tr), 20.0, 0.0,
                                     200.0);
        let fused = p.peak();
        assert_eq!(fused.0.to_bits(), t_lo.to_bits());
        assert_eq!(fused.1.to_bits(), t_hi.to_bits());
        assert_eq!(fused.2, n);
    }

    /// Exact-match memoization is output-neutral: the warm planner's
    /// schedule is bitwise the cold planner's, and on a plateau it pays
    /// for one full solve instead of one per epoch.
    #[test]
    fn warm_schedule_matches_cold_bitwise() {
        use crate::planner::fused::DemandProfile;
        use crate::workload::RequestClass;
        let (m, template, cfg, slo) = controller_inputs();
        let tr: Vec<Request> = (0..240)
            .map(|i| Request {
                id: i,
                arrival_s: i as f64 + 0.5,
                prompt_tokens: 256,
                output_tokens: 128,
                class: RequestClass::Online,
            })
            .collect();
        let h = HorizonConfig::default();
        let ci = CiSignal::flat(261.0);
        let epoch = h.effective_epoch(240.0);
        let profile = DemandProfile::build(&mut SliceSource::new(&tr), epoch,
                                           h.window_s, 240.0);
        let mut cold = IncrementalPlanner::disabled();
        let a = plan_schedule_from_profile(m, &profile, &template, &cfg, &ci,
                                           slo, &h, 240.0, &mut cold);
        let mut warm = IncrementalPlanner::from_horizon(&h);
        let b = plan_schedule_from_profile(m, &profile, &template, &cfg, &ci,
                                           slo, &h, 240.0, &mut warm);
        assert_eq!(a, b, "memoized schedule diverged from cold re-solves");
        let s = warm.stats();
        assert_eq!(s.full_solves, 1, "plateau should solve once: {s:?}");
        assert_eq!(s.warm_hits, s.epochs - 1, "{s:?}");
        assert_eq!(cold.stats().full_solves, cold.stats().epochs);
    }

    /// Creep protection: drift is measured against the demand the plan was
    /// last *solved* for, so a slow ramp accumulates until it trips the
    /// tolerance instead of being re-absorbed skip after skip.
    #[test]
    fn drift_skip_never_outlives_the_tolerance() {
        use crate::planner::fused::DemandProfile;
        use crate::workload::RequestClass;
        // 10 → ~20 arrivals/s ramp at a fixed length: per-epoch drift is a
        // few percent (under tol), but it compounds across epochs.
        let mut tr = Vec::new();
        for s in 0..300u64 {
            for j in 0..(10 + s / 30) {
                tr.push(Request {
                    id: s * 32 + j,
                    arrival_s: s as f64 + (j as f64 + 0.5) / 32.0,
                    prompt_tokens: 256,
                    output_tokens: 128,
                    class: RequestClass::Online,
                });
            }
        }
        let (m, template, cfg, slo) = controller_inputs();
        let h = HorizonConfig { drift_tol: 0.2, ..Default::default() };
        let ci = CiSignal::flat(261.0);
        let epoch = h.effective_epoch(300.0);
        let profile = DemandProfile::build(&mut SliceSource::new(&tr), epoch,
                                           h.window_s, 300.0);
        let mut inc = IncrementalPlanner::from_horizon(&h);
        let sched = plan_schedule_from_profile(m, &profile, &template, &cfg,
                                               &ci, slo, &h, 300.0, &mut inc);
        assert!(sched.events.windows(2).all(|w| w[0].t <= w[1].t));
        let s = inc.stats();
        assert!(s.drift_skips > 0, "tolerance never engaged: {s:?}");
        assert!(s.full_solves > 1,
                "a ramp past the tolerance must re-solve: {s:?}");
        assert_eq!(s.epochs,
                   s.full_solves + s.warm_hits + s.drift_skips + s.cut_patches);
    }

    /// With interval cuts on, a step surge at constant request shape is
    /// absorbed by patching the master plan instead of a full re-solve.
    #[test]
    fn step_surge_takes_the_cut_path() {
        use crate::planner::fused::DemandProfile;
        use crate::workload::RequestClass;
        let mut tr = Vec::new();
        let mut id = 0u64;
        for s in 0..300u64 {
            let n = if (150..225).contains(&s) { 12 } else { 3 };
            for j in 0..n {
                tr.push(Request {
                    id,
                    arrival_s: s as f64 + (j as f64 + 0.5) / 16.0,
                    prompt_tokens: 256,
                    output_tokens: 128,
                    class: RequestClass::Online,
                });
                id += 1;
            }
        }
        let (m, template, cfg, slo) = controller_inputs();
        let h = HorizonConfig { interval_cuts: true, ..Default::default() };
        let ci = CiSignal::flat(261.0);
        let epoch = h.effective_epoch(300.0);
        let profile = DemandProfile::build(&mut SliceSource::new(&tr), epoch,
                                           h.window_s, 300.0);
        let mut inc = IncrementalPlanner::from_horizon(&h);
        let sched = plan_schedule_from_profile(m, &profile, &template, &cfg,
                                               &ci, slo, &h, 300.0, &mut inc);
        assert!(sched.events.windows(2).all(|w| w[0].t <= w[1].t));
        let s = inc.stats();
        assert!(s.cut_patches > 0, "surge never took the cut path: {s:?}");
        assert!(s.full_solves < s.epochs, "{s:?}");
    }

    #[test]
    fn effective_epoch_clamps() {
        let h = HorizonConfig { epoch_s: 1000.0, ..Default::default() };
        assert_eq!(h.effective_epoch(100.0), 50.0);
        let h = HorizonConfig { epoch_s: 0.1, ..Default::default() };
        assert_eq!(h.effective_epoch(960.0), 10.0);
        let h = HorizonConfig { epoch_s: 15.0, ..Default::default() };
        assert_eq!(h.effective_epoch(180.0), 15.0);
    }
}
