//! Scenario-sweep engine: named end-to-end design points wired through
//! `config → planner → solver → sim → carbon` (DESIGN.md §5).
//!
//! A [`Scenario`] is a declarative [`ScenarioSpec`] — model, region,
//! strategy, workload mix, fleet policy — plus a name; [`registry`]
//! (catalog.rs) holds the shipped design points and [`run_sweep`]
//! (runner.rs) executes any subset in parallel with deterministic
//! per-scenario seeds. Every future perf/scale PR benchmarks against this
//! substrate: `ecoserve sweep --all` reproduces the whole matrix in one
//! command and emits machine-readable JSON.
//!
//! Determinism contract: the same (scenario name, master seed, duration)
//! triple produces byte-identical [`ScenarioOutcome`] JSON regardless of
//! thread count or co-scheduled scenarios. Seeds derive from the scenario
//! *name* (not its registry position), wall-clock fields are excluded from
//! the JSON, and MILP truncation is node-bound rather than time-bound.

pub mod catalog;
pub mod runner;

pub use catalog::registry;
pub use runner::{run_sweep, SweepConfig, SweepReport};

use crate::carbon::ci_stream::CiStream;
use crate::carbon::intensity::{CiSignal, CiTrace, Region};
use crate::obs::{ObsArtifacts, ObsSettings, Observer, Profile};
use crate::planner::fused::DemandProfile;
use crate::planner::horizon::{self, HorizonConfig, IncrementalPlanner,
                              PlannerStats};
use crate::planner::slicing::SliceAccum;
use crate::planner::{self, PlanConfig};
use crate::sim::{apply_ci_spikes, shard, simulate_stream,
                 simulate_stream_observed, DeferralPolicy, FaultPlan,
                 FleetSchedule, KeepAlivePolicy, Router, SimConfig,
                 SimReport};
use crate::strategies::{fleet_from_plan, hetero_pd_fleet, sim_config,
                        splitwise_fleet, Strategy};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::slo::{slo_for, Slo};
use crate::workload::{merge_traces, Arrivals, ArrivalSource, GeneratorSource,
                      LengthDist, MergedSource, Request, RequestClass,
                      SliceSource, TraceDialect, TraceErrorPolicy,
                      TraceRescale, TraceSource};
use std::collections::BTreeMap;
use std::time::Duration;

/// Window count for the burstiness extras panel on trace-replay scenarios:
/// fine enough to resolve diurnal peaks, coarse enough that a day-long
/// replay keeps tens of arrivals per window.
const BURST_WINDOWS: usize = 48;

/// One workload component of a scenario (a trace generator).
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub arrivals: Arrivals,
    pub lengths: LengthDist,
    pub class: RequestClass,
}

/// How the simulated fleet is derived from the plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetPolicy {
    /// Planner-provisioned fleet (mixed/disaggregated roles from loads).
    Planned,
    /// Splitwise-style fixed 3:1 prompt/token H100 split sized to the
    /// plan's GPU count (paper §6.2.1).
    SplitwisePd,
    /// Planner fleet split across two grids: alternate servers are pinned
    /// to the `low`-CI region, the rest stay in the primary region — the
    /// substrate for carbon-aware routing studies.
    TwoRegion { low: Region },
    /// GreenLLM-style heterogeneous disaggregation sized to the plan's
    /// GPU count: current-generation H100 prefill servers in front of a
    /// decode tier recycled from the oldest reliability-safe catalog GPU
    /// ([`crate::strategies::hetero_pd_fleet`]).
    HeteroPd,
}

/// Scenario grouping for `sweep --pack`: the core synthetic design
/// points, the production-trace replays, and the fault-injection /
/// graceful-degradation studies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pack {
    Core,
    Replay,
    Failure,
}

impl Pack {
    pub fn name(&self) -> &'static str {
        match self {
            Pack::Core => "core",
            Pack::Replay => "replay",
            Pack::Failure => "failure",
        }
    }

    /// Parse a CLI `--pack` argument.
    pub fn parse(s: &str) -> Option<Pack> {
        match s {
            "core" => Some(Pack::Core),
            "replay" => Some(Pack::Replay),
            "failure" => Some(Pack::Failure),
            _ => None,
        }
    }
}

/// Shape of the primary region's CI signal over the simulated trace.
/// No longer `Copy`: [`CiProfile::TraceFile`] owns its path — clone at
/// use sites instead.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CiProfile {
    /// Flat at the region's published average.
    Flat,
    /// One synthetic solar day compressed onto the trace duration
    /// ([`CiTrace::compressed_diurnal`]) so short sweeps see intra-day
    /// swings.
    CompressedDiurnal,
    /// Seven compressed solar days across the trace duration — pairs with
    /// [`Arrivals::Week`] so a production week sees demand and grid CI
    /// cycle together.
    CompressedWeek,
    /// A recorded grid-CI trace streamed from a CSV file
    /// ([`crate::carbon::ci_stream`]): the file's extent maps onto the
    /// run duration and the planner's epoch forecast reads it through a
    /// chunked lookahead window instead of a materialized trace.
    TraceFile { path: String },
}

/// A declarative end-to-end design point.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    /// Model name from [`crate::models::catalog`].
    pub model: &'static str,
    /// Primary deployment region (sets the planning CI).
    pub region: Region,
    /// Provisioning strategy whose planner configuration is used.
    pub strategy: Strategy,
    /// Override the strategy's GPU menu (e.g. a legacy-hardware pool).
    pub gpu_menu: Option<Vec<&'static str>>,
    /// Workload mix; traces are generated and merged per component.
    pub workloads: Vec<WorkloadSpec>,
    /// Online SLO override (defaults to the paper's §5 table entry).
    pub slo: Option<Slo>,
    pub fleet: FleetPolicy,
    pub router: Router,
    /// Shape of the primary region's CI signal.
    pub ci_profile: CiProfile,
    /// Temporally shift offline work into low-CI windows (the paper's
    /// Reduce lever); the run-immediately baseline lands in `extras`.
    pub defer_offline: bool,
    /// Rolling-horizon re-provisioning: the fleet is the *peak* plan, and
    /// the [`horizon`] controller re-solves the allocation ILP each epoch
    /// to drain/re-provision servers against observed demand and the CI
    /// forecast. The static peak-provisioned baseline lands in `extras`
    /// (`carbon_kg_static`, …).
    pub reprovision: Option<HorizonConfig>,
    /// Extra regions to cross-report carbon for (operational rescales
    /// linearly with CI; embodied is region-independent).
    pub compare_regions: Vec<Region>,
    /// Cold-start delay (s) between a provisioning decision and the
    /// server admitting work; 0.0 keeps the instant-activation engine.
    pub coldstart_s: f64,
    /// What drained-empty servers do: retire at once, or stay warm for a
    /// window (paying idle carbon against the next surge's cold starts).
    pub keepalive: KeepAlivePolicy,
    /// DVFS frequency scale applied to the fleet's decode phase (decode
    /// is memory-bound, so downclocking trades a little latency for an
    /// f³ cut in dynamic power). 1.0 = stock clocks, bit-identical.
    pub decode_freq: f64,
    /// Deterministic fault plan with event times as *fractions* of the
    /// run duration ([`FaultPlan::scale_to`] converts at run time), so one
    /// spec stresses any `--duration`. Empty plans inject nothing and are
    /// byte-neutral; non-empty plans land a fault-free twin run in
    /// `extras` (`*_nofault`).
    pub faults: FaultPlan,
}

/// CLI `--trace` override: replay a request-trace file as the scenario's
/// entire workload, replacing the spec's synthetic components (the
/// fastest way to point any registry design point at a recorded stream).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceOverride {
    pub path: String,
    pub dialect: TraceDialect,
    pub errors: TraceErrorPolicy,
    /// Load multiplier (see [`TraceRescale::rate`]).
    pub rate: f64,
}

/// Sweep-level spec overrides (the CLI's `--ci-trace` / `--epoch` knobs).
#[derive(Debug, Clone, Default)]
pub struct Overrides {
    /// Force a CI-signal shape on the scenario.
    pub ci_profile: Option<CiProfile>,
    /// Override the re-provisioning epoch (seconds) for scenarios that
    /// run the rolling-horizon controller; ignored for static fleets.
    pub epoch_s: Option<f64>,
    /// Run on the sharded runtime with up to N shard worker threads (the
    /// CLI `--shards` knob); `None` keeps the single-core engine. The
    /// fleet partition never depends on N, so the outcome bytes are
    /// invariant in N — N only buys wall-clock.
    pub shards: Option<usize>,
    /// Force a cold-start delay (the CLI `--coldstart` knob).
    pub coldstart_s: Option<f64>,
    /// Force a keep-alive policy (the CLI `--keepalive` knob).
    pub keepalive: Option<KeepAlivePolicy>,
    /// Replace the scenario's workloads with a trace replay (the CLI
    /// `--trace` knob).
    pub trace: Option<TraceOverride>,
    /// Replace the scenario's CI profile with a file-backed signal (the
    /// CLI `--ci-file` knob).
    pub ci_file: Option<String>,
}

/// A named design point that the sweep runner can execute.
pub trait Scenario: Send + Sync {
    fn name(&self) -> &'static str;
    fn description(&self) -> &'static str;
    fn spec(&self) -> ScenarioSpec;

    /// Which `sweep --pack` group this design point belongs to.
    fn pack(&self) -> Pack {
        Pack::Core
    }

    /// Scale scenarios sized for explicit long `--duration` runs (e.g. a
    /// multi-million-request production week). The CLI skips these in
    /// `--all` sweeps unless a duration was given; selecting them by name
    /// always runs them.
    fn long_haul(&self) -> bool {
        false
    }

    /// Run the full pipeline at a seed/duration. Deterministic.
    fn run(&self, seed: u64, duration_s: f64) -> ScenarioOutcome {
        self.run_with(seed, duration_s, &Overrides::default())
    }

    /// The spec with sweep-level overrides applied — shared by the
    /// observed and unobserved run paths so they exercise identical
    /// configurations.
    fn spec_with(&self, ov: &Overrides) -> ScenarioSpec {
        let mut spec = self.spec();
        if let Some(p) = &ov.ci_profile {
            spec.ci_profile = p.clone();
        }
        if let (Some(e), Some(h)) = (ov.epoch_s, spec.reprovision.as_mut()) {
            h.epoch_s = e;
        }
        if let Some(cs) = ov.coldstart_s {
            spec.coldstart_s = cs;
        }
        if let Some(ka) = ov.keepalive {
            spec.keepalive = ka;
        }
        if let Some(t) = &ov.trace {
            spec.workloads = vec![WorkloadSpec {
                arrivals: Arrivals::Trace {
                    path: t.path.clone(),
                    dialect: t.dialect,
                    rescale: TraceRescale { fit_duration: true, rate: t.rate },
                    errors: t.errors,
                },
                lengths: LengthDist::ShareGpt, // ignored: the trace has lengths
                class: RequestClass::Online,
            }];
        }
        if let Some(p) = &ov.ci_file {
            spec.ci_profile = CiProfile::TraceFile { path: p.clone() };
        }
        spec
    }

    /// Like [`Scenario::run`] with sweep-level spec overrides.
    fn run_with(&self, seed: u64, duration_s: f64, ov: &Overrides)
        -> ScenarioOutcome {
        let spec = self.spec_with(ov);
        match ov.shards {
            Some(n) => run_spec_sharded(self.name(), &spec, seed, duration_s, n),
            None => run_spec(self.name(), &spec, seed, duration_s),
        }
    }

    /// [`Scenario::run_with`] carrying the passive observability
    /// recorders ([`crate::obs`]) on the primary pass; baselines run
    /// unobserved. The outcome bytes are identical to [`Scenario::run_with`]
    /// — the recorders never touch simulation state.
    fn run_observed(&self, seed: u64, duration_s: f64, ov: &Overrides,
                    obs: &ObsSettings) -> (ScenarioOutcome, ObsArtifacts) {
        let spec = self.spec_with(ov);
        run_spec_observed(self.name(), &spec, seed, duration_s, ov.shards, obs)
    }
}

/// Per-scenario sweep result. Everything here is deterministic for a
/// (name, seed, duration) triple — no wall-clock fields.
#[derive(Debug, Clone)]
pub struct ScenarioOutcome {
    pub name: String,
    pub seed: u64,
    pub model: String,
    pub region: String,
    pub ci: f64,
    /// Requests in the generated trace.
    pub requests: usize,
    pub completed: usize,
    pub generated_tokens: usize,
    /// Discrete events processed by the sim core — the capacity study's
    /// throughput numerator (`ecoserve scale` reports events/sec).
    pub events: usize,
    /// Provisioned GPUs (plan) and simulated servers (TP groups).
    pub fleet_gpus: usize,
    pub fleet_servers: usize,
    pub counts: BTreeMap<String, usize>,
    pub plan_cost_hr: f64,
    pub plan_op_kg_per_hr: f64,
    pub plan_emb_kg_per_hr: f64,
    pub ttft_p50_s: f64,
    pub ttft_p90_s: f64,
    pub ttft_p99_s: f64,
    pub tpot_p50_s: f64,
    pub tpot_p90_s: f64,
    pub tpot_p99_s: f64,
    pub throughput_tok_s: f64,
    pub energy_j: f64,
    pub op_kg: f64,
    pub emb_kg: f64,
    pub slo_attainment: f64,
    /// Offline deadline attainment (1.0 when no deadlines are tracked).
    pub offline_deadline_attainment: f64,
    /// Offline requests shifted into low-CI release slots.
    pub deferred: usize,
    /// Requests whose prompts were clipped to the sim's context cap.
    pub truncated_prompts: usize,
    /// Servers brought online / decommissioned by the rolling-horizon
    /// controller (both 0 for static fleets).
    pub provision_events: usize,
    pub decommission_events: usize,
    /// High-water mark of concurrently live jobs in the streaming core's
    /// arena — the scale scenarios assert this stays far below `requests`.
    pub peak_live_jobs: usize,
    /// Provisioned server-hours the embodied and idle carbon amortize
    /// over (static fleets: servers × duration).
    pub provisioned_server_hours: f64,
    /// Scenario-specific extra metrics (e.g. per-region carbon).
    pub extras: BTreeMap<String, f64>,
}

impl ScenarioOutcome {
    pub fn carbon_kg(&self) -> f64 {
        self.op_kg + self.emb_kg
    }

    pub fn to_json(&self) -> Json {
        let mut counts = Json::obj();
        for (k, v) in &self.counts {
            counts = counts.set(k, *v);
        }
        let mut extras = Json::obj();
        for (k, v) in &self.extras {
            extras = extras.set(k, jnum(*v));
        }
        Json::obj()
            .set("name", self.name.as_str())
            .set("seed", format!("{:#018x}", self.seed))
            .set("model", self.model.as_str())
            .set("region", self.region.as_str())
            .set("ci_g_per_kwh", jnum(self.ci))
            .set("requests", self.requests)
            .set("completed", self.completed)
            .set("generated_tokens", self.generated_tokens)
            .set("events", self.events)
            .set("fleet_gpus", self.fleet_gpus)
            .set("fleet_servers", self.fleet_servers)
            .set("fleet_counts", counts)
            .set("plan_cost_hr", jnum(self.plan_cost_hr))
            .set("plan_op_kg_per_hr", jnum(self.plan_op_kg_per_hr))
            .set("plan_emb_kg_per_hr", jnum(self.plan_emb_kg_per_hr))
            .set("ttft_p50_s", jnum(self.ttft_p50_s))
            .set("ttft_p90_s", jnum(self.ttft_p90_s))
            .set("ttft_p99_s", jnum(self.ttft_p99_s))
            .set("tpot_p50_s", jnum(self.tpot_p50_s))
            .set("tpot_p90_s", jnum(self.tpot_p90_s))
            .set("tpot_p99_s", jnum(self.tpot_p99_s))
            .set("throughput_tok_s", jnum(self.throughput_tok_s))
            .set("energy_j", jnum(self.energy_j))
            .set("op_kg", jnum(self.op_kg))
            .set("emb_kg", jnum(self.emb_kg))
            .set("carbon_kg", jnum(self.carbon_kg()))
            .set("slo_attainment", jnum(self.slo_attainment))
            .set("offline_deadline_attainment",
                 jnum(self.offline_deadline_attainment))
            .set("deferred_requests", self.deferred)
            .set("truncated_prompts", self.truncated_prompts)
            .set("provision_events", self.provision_events)
            .set("decommission_events", self.decommission_events)
            .set("peak_live_jobs", self.peak_live_jobs)
            .set("provisioned_server_hours", jnum(self.provisioned_server_hours))
            .set("extras", extras)
    }
}

/// Non-finite floats have no JSON representation; map them to null.
fn jnum(x: f64) -> Json {
    if x.is_finite() { Json::Num(x) } else { Json::Null }
}

/// Deterministic per-scenario seed: FNV-1a of the scenario *name* mixed
/// with the master seed. Independent of registry order and thread count.
pub fn scenario_seed(master: u64, name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h ^ master.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Planner configuration for a scenario: the strategy's config with a
/// deterministic MILP budget (node-bound, not wall-clock-bound) and an
/// optional GPU-menu override.
fn scenario_plan_config(spec: &ScenarioSpec, ci: f64) -> PlanConfig {
    let mut cfg = spec.strategy.plan_config(ci);
    if let Some(menu) = &spec.gpu_menu {
        cfg.gpu_menu = menu.clone();
    }
    cfg.milp.max_nodes = 500;
    cfg.milp.time_limit = Duration::from_secs(3600);
    cfg
}

/// One workload component as a lazy stream: a [`GeneratorSource`] for the
/// synthetic processes, a [`TraceSource`] replay for [`Arrivals::Trace`].
/// Trace files were chosen/validated by whoever built the spec, so a file
/// that fails to open here is a broken deployment, not a recoverable
/// condition (the CLI pre-validates its `--trace` inputs and exits
/// cleanly before reaching this panic).
fn workload_source(w: &WorkloadSpec, duration_s: f64, seed: u64)
    -> Box<dyn ArrivalSource + 'static> {
    match &w.arrivals {
        Arrivals::Trace { path, dialect, rescale, errors } => Box::new(
            TraceSource::open(path, *dialect, *errors, *rescale, w.class,
                              duration_s)
                .unwrap_or_else(|e| panic!("{e}"))),
        arrivals => Box::new(GeneratorSource::new(
            arrivals.clone(), w.lengths, w.class, duration_s, seed)),
    }
}

/// Lazy multi-class merged source for a spec: per-component sources under
/// a k-way merge, with workload seeds derived from the scenario seed in
/// component order — the same per-name deterministic seeds the
/// materialized path uses. Trace components draw (and discard) a seed
/// too, so adding a replay component never re-seeds its neighbors.
fn scenario_sources(spec: &ScenarioSpec, seed: u64, duration_s: f64)
    -> MergedSource<Box<dyn ArrivalSource + 'static>> {
    let mut root = Rng::new(seed);
    MergedSource::new(
        spec.workloads
            .iter()
            .map(|w| workload_source(w, duration_s, root.next_u64()))
            .collect())
}

/// Generate the merged trace for a spec. Workload seeds derive from the
/// scenario seed in component order (identical to [`scenario_sources`]).
fn scenario_trace(spec: &ScenarioSpec, seed: u64, duration_s: f64) -> Vec<Request> {
    let mut root = Rng::new(seed);
    let traces = spec
        .workloads
        .iter()
        .map(|w| workload_source(w, duration_s, root.next_u64()).materialize())
        .collect();
    merge_traces(traces)
}

/// Execute one design point end to end over lazy arrival streams:
/// stream → slices → planner (ILP) → fleet → cluster sim → carbon.
/// Memory stays bounded by the fleet, the in-flight jobs, and (for
/// re-provisioning scenarios) one observation window of demand.
pub fn run_spec(name: &str, spec: &ScenarioSpec, seed: u64, duration_s: f64)
    -> ScenarioOutcome {
    let fresh = || {
        Box::new(scenario_sources(spec, seed, duration_s)) as Box<dyn ArrivalSource>
    };
    run_spec_with_sources(name, spec, seed, duration_s, &fresh, None, None).0
}

/// [`run_spec`]/[`run_spec_sharded`] with the passive observability
/// recorders attached to the primary pass: the outcome bytes are
/// identical; the second element carries the rendered timeline CSV,
/// Chrome-trace span JSON, and self-profile JSON per `obs` settings.
pub fn run_spec_observed(name: &str, spec: &ScenarioSpec, seed: u64,
                         duration_s: f64, shards: Option<usize>,
                         obs: &ObsSettings) -> (ScenarioOutcome, ObsArtifacts) {
    let fresh = || {
        Box::new(scenario_sources(spec, seed, duration_s)) as Box<dyn ArrivalSource>
    };
    let (out, art) = run_spec_with_sources(name, spec, seed, duration_s,
                                           &fresh, shards.map(|n| n.max(1)),
                                           Some(obs));
    (out, art.unwrap_or_default())
}

/// [`run_spec`] on the sharded runtime: the same global planning passes,
/// then the fleet partitions into per-region/per-cluster shards that
/// simulate (and, for re-provisioning scenarios, schedule) their own
/// deterministic substreams on up to `shards` scoped threads. The
/// outcome bytes are invariant in `shards` — the partition comes from the
/// fleet, never from the thread budget.
pub fn run_spec_sharded(name: &str, spec: &ScenarioSpec, seed: u64,
                        duration_s: f64, shards: usize) -> ScenarioOutcome {
    let fresh = || {
        Box::new(scenario_sources(spec, seed, duration_s)) as Box<dyn ArrivalSource>
    };
    run_spec_with_sources(name, spec, seed, duration_s, &fresh,
                          Some(shards.max(1)), None).0
}

/// Reference implementation for the differential suite: materialize the
/// full trace once (the pre-streaming behavior) and run the identical
/// pipeline through [`SliceSource`] adapters. Must produce byte-identical
/// [`ScenarioOutcome`] JSON to [`run_spec`] — `tests/integration_streaming.rs`
/// enforces this for every registry scenario.
pub fn run_spec_materialized(name: &str, spec: &ScenarioSpec, seed: u64,
                             duration_s: f64) -> ScenarioOutcome {
    let trace = scenario_trace(spec, seed, duration_s);
    let fresh = || {
        Box::new(SliceSource::new(&trace)) as Box<dyn ArrivalSource + '_>
    };
    run_spec_with_sources(name, spec, seed, duration_s, &fresh, None, None).0
}

/// Materialized reference for the *sharded* differential: byte-identical
/// to [`run_spec_sharded`] at any shard count —
/// `tests/integration_shard.rs` enforces it.
pub fn run_spec_sharded_materialized(name: &str, spec: &ScenarioSpec,
                                     seed: u64, duration_s: f64,
                                     shards: usize) -> ScenarioOutcome {
    let trace = scenario_trace(spec, seed, duration_s);
    let fresh = || {
        Box::new(SliceSource::new(&trace)) as Box<dyn ArrivalSource + '_>
    };
    run_spec_with_sources(name, spec, seed, duration_s, &fresh,
                          Some(shards.max(1)), None).0
}

/// Factory handing out a fresh copy of a scenario's arrival stream; each
/// demand pass over the workload pulls its own. `Sync` so shard workers
/// can pull fresh streams concurrently.
type SourceFactory<'a> = dyn Fn() -> Box<dyn ArrivalSource + 'a> + Sync;

/// The shared pipeline: every demand pass (peak-window scan, slicing,
/// horizon scheduling, simulation, baselines) pulls a fresh stream from
/// `fresh`, so the streaming and materialized paths run the *same* code
/// over the same request sequences.
///
/// With `spec.reprovision` set, the one-shot plan is sized on the trace's
/// *peak* epoch window (what a peak-provisioned operator would deploy)
/// and the rolling-horizon controller then schedules provisioning events
/// over that template; the static all-on baseline lands in `extras`.
///
/// With `shards` set, every simulation pass (main run and baselines) runs
/// on the sharded runtime: the fleet partitions per region/cluster, each
/// shard re-provisions against and simulates its own substream, and the
/// merged report is invariant in the thread budget.
fn run_spec_with_sources<'a>(name: &str, spec: &ScenarioSpec, seed: u64,
                             duration_s: f64, fresh: &SourceFactory<'a>,
                             shards: Option<usize>, obs: Option<&ObsSettings>)
    -> (ScenarioOutcome, Option<ObsArtifacts>) {
    use crate::planner::slicing::cluster_slices;

    let model = crate::models::llm(spec.model)
        .unwrap_or_else(|| panic!("scenario {name}: unknown model {}", spec.model));
    let ci = spec.region.avg_ci();
    let slo = spec.slo
        .or_else(|| slo_for(spec.model, false).map(|w| w.slo))
        .unwrap_or(Slo { ttft_s: 2.0, tpot_s: 0.2 });

    // Harness self-profile: stage wall clocks + planner epoch counters.
    // Always collected (a pair of `Instant` reads per stage); rendered
    // only when observability asked for it. Wall clocks never feed the
    // outcome, so the observed and unobserved paths stay byte-identical.
    let mut prof = Profile::default();

    let plan_cfg = scenario_plan_config(spec, ci);
    // Re-provisioning scenarios used to walk the stream three times before
    // simulating (peak scan, peak re-materialization, sliding observation
    // buffer); one fused [`DemandProfile`] pass now feeds both the
    // peak-window plan and the rolling-horizon controller. Sharded runs
    // build it on the shard thread budget — byte-identical by contract.
    let profile = spec.reprovision.as_ref().map(|h| {
        let epoch = h.effective_epoch(duration_s);
        prof.stage(|p| &mut p.demand_pass_s, || match shards {
            None => DemandProfile::build(&mut *fresh(), epoch, h.window_s,
                                         duration_s),
            Some(threads) => DemandProfile::build_sharded(
                fresh, threads, epoch, h.window_s, duration_s),
        })
    });
    let plan = match &profile {
        Some(profile) => {
            // The one-shot plan is sized on the peak epoch window's slice
            // histogram — same bytes the old scan-then-rewalk produced
            // (the grid accumulates under the identical membership test).
            let slices = cluster_slices(
                &profile.peak_accum().slices(model, profile.epoch_s, slo, 1));
            planner::plan(&slices, &plan_cfg)
        }
        None => {
            let mut acc = SliceAccum::new();
            let mut src = fresh();
            while let Some(r) = src.next_request() {
                acc.push(&r);
            }
            let slices = cluster_slices(&acc.slices(model, duration_s, slo, 1));
            planner::plan(&slices, &plan_cfg)
        }
    };

    let fleet = match spec.fleet {
        FleetPolicy::Planned => fleet_from_plan(&plan, model, 2048),
        FleetPolicy::SplitwisePd => {
            let total = plan.total_gpus().max(4);
            let prompt = (total * 3 / 4).max(1);
            let token = (total - prompt).max(1);
            splitwise_fleet(model, prompt, token, 2048)
        }
        FleetPolicy::TwoRegion { low } => {
            let mut fleet = fleet_from_plan(&plan, model, 2048);
            for (i, s) in fleet.iter_mut().enumerate() {
                // Alternate so both grids hold prompt-capable servers
                // whatever roles the plan assigned. Only the low-CI half
                // is pinned; the rest follows the primary CI signal, so a
                // diurnal profile still reaches half the fleet.
                s.region = if i % 2 == 0 { Some(low) } else { None };
            }
            fleet
        }
        FleetPolicy::HeteroPd => {
            // Same 3:1 sizing convention as SplitwisePd, but the decode
            // tier comes from the recycled-GPU reliability screen.
            let total = plan.total_gpus().max(4);
            let prompt = (total * 3 / 4).max(1);
            let token = (total - prompt).max(1);
            hetero_pd_fleet(model, prompt, token, 2048)
        }
    };
    let fleet_servers = fleet.len();
    let mut cfg = sim_config(fleet, &plan, ci);
    cfg.router = spec.router;
    cfg.coldstart_s = spec.coldstart_s;
    cfg.keepalive = spec.keepalive;
    if spec.decode_freq != 1.0 {
        for s in &mut cfg.servers {
            s.device.decode_freq = spec.decode_freq;
        }
    }
    cfg.ci = match &spec.ci_profile {
        CiProfile::Flat => CiSignal::flat(ci),
        CiProfile::CompressedDiurnal => CiSignal::Trace(
            CiTrace::compressed_diurnal(spec.region, duration_s, 2, 96,
                                        seed ^ 0xD1A)),
        // 8 periods of duration/7: like the diurnal profile's 2x-duration
        // trace, the extra cycle keeps post-trace-end completion time on a
        // live diurnal signal instead of a clamped final step.
        CiProfile::CompressedWeek => CiSignal::Trace(
            CiTrace::compressed_diurnal(spec.region, duration_s / 7.0, 8, 96,
                                        seed ^ 0xD1A)),
        // File-backed signal: the planner's epoch forecast and the sim's
        // interval integrals read a chunked window over the file instead
        // of a materialized trace. Committed-fixture scenarios fail loud
        // on a broken checkout; CLI-supplied files were pre-validated.
        CiProfile::TraceFile { path } => CiSignal::Streaming(
            CiStream::open(path, spec.region, duration_s)
                .unwrap_or_else(|e| panic!("scenario {name}: {e}"))),
    };
    // Per-region CI traces: under a time-varying profile, the pinned half
    // of a TwoRegion fleet gets its *own* compressed diurnal day,
    // phase-shifted by the longitude gap between the grids — both grids
    // see diurnal CI instead of the pinned one flat-lining at its
    // average.
    if let FleetPolicy::TwoRegion { low } = spec.fleet {
        let day = match &spec.ci_profile {
            CiProfile::Flat => None,
            CiProfile::CompressedDiurnal => Some((duration_s, 2)),
            CiProfile::CompressedWeek => Some((duration_s / 7.0, 8)),
            // The file describes the *primary* grid; give the pinned grid
            // one phase-shifted synthetic solar day so it still sees
            // diurnal CI rather than flat-lining at its average.
            CiProfile::TraceFile { .. } => Some((duration_s, 2)),
        };
        if let Some((period_s, periods)) = day {
            cfg.region_signals = vec![(
                low,
                CiSignal::Trace(CiTrace::compressed_diurnal_shifted(
                    low, period_s, periods, 96, seed ^ 0xD1B,
                    low.solar_offset_hours(spec.region))),
            )];
        }
    }
    if spec.defer_offline {
        cfg.deferral = DeferralPolicy::LowCiWindow {
            deadline_s: 0.8 * duration_s,
            spacing_s: 0.3,
            horizon_s: duration_s,
        };
    }
    // Unsharded runs schedule the whole fleet off the fused profile (no
    // extra demand pass); the sharded runtime instead re-provisions each
    // shard against its own substream (see `sched` below).
    if let (Some(h), None, Some(profile)) = (&spec.reprovision, shards, &profile) {
        let mut inc = IncrementalPlanner::from_horizon(h);
        cfg.fleet_plan = prof.stage(|p| &mut p.plan_s, || {
            horizon::plan_schedule_from_profile(
                model, profile, &cfg.servers, &plan_cfg, &cfg.ci, slo, h,
                duration_s, &mut inc)
        });
        prof.add_planner(inc.stats());
    }

    // Fault injection: the spec's fraction-typed fault times scale onto
    // this run's duration, CI-spike windows transform the (already built)
    // grid signals, and the server-level faults hand to the engine. The
    // planner above saw the *unspiked* signals — an outage is an
    // unforecast event, not something the ILP gets to hedge against. The
    // pre-fault twin config backs the `*_nofault` extras baseline.
    let faults = spec.faults.scale_to(duration_s);
    let nofault_cfg = (!faults.is_empty()).then(|| cfg.clone());
    if !faults.is_empty() {
        cfg.ci = apply_ci_spikes(&cfg.ci, spec.region, &faults, duration_s);
        let signals = std::mem::take(&mut cfg.region_signals);
        cfg.region_signals = signals
            .into_iter()
            .map(|(rg, sig)| {
                let spiked = apply_ci_spikes(&sig, rg, &faults, duration_s);
                (rg, spiked)
            })
            .collect();
        cfg.faults = faults;
    }

    // The partition is a pure function of the fleet, shared by the main
    // run and every baseline below (their fleets are identical).
    let shard_ctx = shards.map(|threads| {
        (shard::ShardPlan::partition(&cfg, seed), threads)
    });
    let plan_cfg_ref = &plan_cfg;
    // Sharded runs build their schedules inside the shard workers; the
    // planner counters fold through a mutex (usize sums commute, so the
    // total is thread-invariant). Only the primary pass records — the
    // flag drops before the baselines re-schedule their twins.
    let planner_stats = std::sync::Mutex::new(PlannerStats::default());
    let planner_recording = std::sync::atomic::AtomicBool::new(true);
    let sched = spec.reprovision.as_ref().map(|h| {
        let stats = &planner_stats;
        let recording = &planner_recording;
        Box::new(move |sub: &SimConfig, src: &mut dyn ArrivalSource| {
            let (schedule, st) = horizon::plan_schedule_stream_with_stats(
                model, src, &sub.servers, plan_cfg_ref, &sub.ci, slo, h,
                duration_s);
            if recording.load(std::sync::atomic::Ordering::Relaxed) {
                stats.lock().unwrap().absorb(st);
            }
            schedule
        }) as Box<shard::ScheduleFn<'_>>
    });
    // One simulation pass: `reprovision` says whether this pass runs the
    // rolling-horizon controller (the static baseline switches it off).
    let run_sim = |c: &SimConfig, reprovision: bool| -> SimReport {
        match &shard_ctx {
            None => simulate_stream(model, &mut *fresh(), c, slo.ttft_s,
                                    slo.tpot_s),
            Some((sp, threads)) => shard::simulate_sharded(
                model, c, slo.ttft_s, slo.tpot_s, sp, *threads, fresh,
                if reprovision { sched.as_deref() } else { None }),
        }
    };
    // Passive observability rides the primary pass only; baselines run
    // unobserved. The observer is built *after* the fault transforms so
    // its timeline CI columns read the signals the engine integrates.
    let mut observer = obs.and_then(|settings| {
        let any = settings.timeline_interval_s.is_some()
            || settings.trace_jobs_rate > 0.0
            || settings.progress_s.is_some();
        any.then(|| {
            let ci_names = std::iter::once("ci_primary".to_string())
                .chain(cfg.region_signals.iter()
                           .map(|(rg, _)| format!("ci_{rg:?}")))
                .collect();
            Observer::for_run(settings, duration_s,
                              seed ^ 0x9E37_79B9_7F4A_7C15, ci_names,
                              cfg.servers.len())
        })
    });
    let r: SimReport = match observer.as_mut() {
        None => prof.stage(|p| &mut p.sim_s, || run_sim(&cfg, true)),
        Some(o) => match &shard_ctx {
            None => prof.stage(|p| &mut p.sim_s, || {
                simulate_stream_observed(model, &mut *fresh(), &cfg,
                                         slo.ttft_s, slo.tpot_s,
                                         cfg.router.policy(),
                                         cfg.batcher.policy(), Some(o))
            }),
            Some((sp, threads)) => {
                let (r, merge_s) = prof.stage(|p| &mut p.sim_s, || {
                    shard::simulate_sharded_observed(
                        model, &cfg, slo.ttft_s, slo.tpot_s, sp, *threads,
                        fresh, sched.as_deref(), Some(o))
                });
                prof.merge_s = merge_s;
                r
            }
        },
    };
    planner_recording.store(false, std::sync::atomic::Ordering::Relaxed);
    prof.add_planner(*planner_stats.lock().unwrap());

    let mut extras = BTreeMap::new();
    // Per-server utilization (busy vs provisioned seconds), surfaced for
    // every scenario from the accounting `ServerUsage` already keeps.
    // Never-provisioned servers are excluded; an empty fleet reads 0.
    let (mut busy, mut prov) = (0.0_f64, 0.0_f64);
    let (mut umin, mut umax) = (f64::INFINITY, f64::NEG_INFINITY);
    for u in &r.per_server {
        if u.provisioned_s > 0.0 {
            let util = u.busy_s / u.provisioned_s;
            umin = umin.min(util);
            umax = umax.max(util);
            busy += u.busy_s;
            prov += u.provisioned_s;
        }
    }
    extras.insert("util_fleet_mean".into(),
                  if prov > 0.0 { busy / prov } else { 0.0 });
    extras.insert("util_server_max".into(),
                  if umax.is_finite() { umax } else { 0.0 });
    extras.insert("util_server_min".into(),
                  if umin.is_finite() { umin } else { 0.0 });
    for region in &spec.compare_regions {
        // Operational carbon scales linearly with grid CI for a fixed
        // energy draw; embodied is region-independent. Normalize by the
        // signal's mean (== the flat average for CiProfile::Flat) so a
        // forced diurnal profile doesn't mis-scale the comparison.
        let op = r.op_kg * region.avg_ci() / cfg.ci.mean().max(1e-9);
        extras.insert(format!("carbon_kg_{region:?}"), op + r.emb_kg);
    }
    if spec.defer_offline {
        // Run-immediately baseline: same trace/fleet/signal, no shifting.
        let mut base_cfg = cfg.clone();
        base_cfg.deferral = DeferralPolicy::Immediate;
        let base = run_sim(&base_cfg, true);
        extras.insert("op_kg_immediate".into(), base.op_kg);
        extras.insert("carbon_kg_immediate".into(), base.carbon_kg());
        extras.insert("slo_attainment_immediate".into(), base.slo_attainment);
        extras.insert("ttft_p90_s_immediate".into(), base.ttft.p90());
    }
    if spec.router == Router::CarbonGreedy {
        // JSQ baseline: identical fleet/grids, carbon-blind routing.
        let mut base_cfg = cfg.clone();
        base_cfg.router = Router::Jsq;
        let base = run_sim(&base_cfg, true);
        extras.insert("op_kg_jsq".into(), base.op_kg);
        extras.insert("carbon_kg_jsq".into(), base.carbon_kg());
        extras.insert("ttft_p90_s_jsq".into(), base.ttft.p90());
    }
    if spec.coldstart_s > 0.0 {
        // Keep-alive policy sweep on the identical elastic schedule: how
        // each policy trades warm-idle carbon against the cold-start SLO
        // misses the next surge pays. The always-warm anchor is the
        // static baseline below (`carbon_kg_static` etc.).
        let panel: [(&str, KeepAlivePolicy); 3] = [
            ("ka_immediate", KeepAlivePolicy::Immediate),
            ("ka_fixed", KeepAlivePolicy::Fixed { window_s: 30.0 }),
            ("ka_hybrid", KeepAlivePolicy::HybridHistogram {
                bin_s: 10.0, percentile: 0.9, max_window_s: 60.0 }),
        ];
        for (label, ka) in panel {
            let mut c = cfg.clone();
            c.keepalive = ka;
            let b = run_sim(&c, true);
            extras.insert(format!("op_kg_{label}"), b.op_kg);
            extras.insert(format!("emb_kg_{label}"), b.emb_kg);
            extras.insert(format!("carbon_kg_{label}"), b.carbon_kg());
            extras.insert(format!("slo_attainment_{label}"), b.slo_attainment);
            extras.insert(format!("ttft_p90_s_{label}"), b.ttft.p90());
            extras.insert(format!("provisioned_server_hours_{label}"),
                          b.provisioned_server_hours);
        }
    }
    if spec.decode_freq != 1.0 {
        // Stock-clock baseline: same fleet at decode_freq = 1.0, so the
        // extras isolate what the f³ dynamic-power cut buys (and what the
        // 1/f decode slowdown costs) on the shared nonlinear curve.
        let mut base_cfg = cfg.clone();
        for s in &mut base_cfg.servers {
            s.device.decode_freq = 1.0;
        }
        let base = run_sim(&base_cfg, true);
        extras.insert("energy_j_stock_freq".into(), base.energy_j);
        extras.insert("op_kg_stock_freq".into(), base.op_kg);
        extras.insert("carbon_kg_stock_freq".into(), base.carbon_kg());
        extras.insert("tpot_p90_s_stock_freq".into(), base.tpot.p90());
        extras.insert("slo_attainment_stock_freq".into(), base.slo_attainment);
    }
    if spec.reprovision.is_some() {
        // Static peak-provisioned baseline: the same template fleet kept
        // fully online for the whole trace — what the elastic schedule
        // must strictly beat on total (op + amortized embodied) carbon.
        let mut base_cfg = cfg.clone();
        base_cfg.fleet_plan = FleetSchedule::default();
        let base = run_sim(&base_cfg, false);
        extras.insert("op_kg_static".into(), base.op_kg);
        extras.insert("emb_kg_static".into(), base.emb_kg);
        extras.insert("carbon_kg_static".into(), base.carbon_kg());
        extras.insert("slo_attainment_static".into(), base.slo_attainment);
        extras.insert("ttft_p90_s_static".into(), base.ttft.p90());
        extras.insert("provisioned_server_hours_static".into(),
                      base.provisioned_server_hours);
    }
    if let Some(base_cfg) = &nofault_cfg {
        // Surface the engine's recovery accounting (golden_schema pins the
        // top-level outcome keys, so fault metrics live in extras) and run
        // the fault-free twin: same trace, fleet, schedule, and unspiked
        // grid signals — the degradation cost in carbon and SLO terms.
        extras.insert("faults_injected".into(), r.faults_injected as f64);
        extras.insert("jobs_rescheduled".into(), r.jobs_rescheduled as f64);
        extras.insert("jobs_recovered".into(), r.jobs_recovered as f64);
        extras.insert("recovery_wait_s".into(), r.recovery_wait_s);
        let base = run_sim(base_cfg, true);
        extras.insert("op_kg_nofault".into(), base.op_kg);
        extras.insert("carbon_kg_nofault".into(), base.carbon_kg());
        extras.insert("slo_attainment_nofault".into(), base.slo_attainment);
        extras.insert("ttft_p90_s_nofault".into(), base.ttft.p90());
    }
    if spec.workloads.iter()
        .any(|w| matches!(w.arrivals, Arrivals::Trace { .. }))
    {
        // Burstiness validation panel: windowed CV and peak-to-mean of
        // the replayed stream next to a Poisson generator matched to its
        // mean rate — the "synthetic generators reproduce production
        // burstiness" claim as numbers instead of a vibe. Plus the trace
        // health counters from the validation pass, so skipped/repaired
        // lines are visible in every report, not just in logs.
        let replay = crate::workload::trace::burstiness(
            &mut *fresh(), duration_s, BURST_WINDOWS);
        let rate = (replay.total as f64 / duration_s).max(1e-9);
        let mut matched = GeneratorSource::new(
            Arrivals::Poisson { rate }, LengthDist::ShareGpt,
            RequestClass::Online, duration_s, seed ^ 0xB57);
        let synth = crate::workload::trace::burstiness(
            &mut matched, duration_s, BURST_WINDOWS);
        extras.insert("burst_cv_replay".into(), replay.cv);
        extras.insert("burst_cv_synthetic".into(), synth.cv);
        extras.insert("burst_peak_to_mean_replay".into(), replay.peak_to_mean);
        extras.insert("burst_peak_to_mean_synthetic".into(),
                      synth.peak_to_mean);
        let (mut records, mut skipped, mut repaired) = (0u64, 0u64, 0u64);
        for w in &spec.workloads {
            if let Arrivals::Trace { path, dialect, errors, .. } = &w.arrivals {
                let st = crate::workload::trace::probe(path, *dialect, *errors)
                    .unwrap_or_else(|e| panic!("scenario {name}: {e}"));
                records += st.records;
                skipped += st.skipped_lines;
                repaired += st.repaired_timestamps;
            }
        }
        extras.insert("trace_records".into(), records as f64);
        extras.insert("trace_skipped_lines".into(), skipped as f64);
        extras.insert("trace_repaired_timestamps".into(), repaired as f64);
    }

    // Render the artifacts last so the profile sees every stage clock.
    // Server track labels use global ids — identical at any shard count.
    let artifacts = obs.map(|settings| {
        let server_labels: Vec<String> = cfg.servers.iter().enumerate()
            .map(|(g, s)| format!("s{g} {}", s.device.name))
            .collect();
        ObsArtifacts {
            timeline_csv: observer.as_ref()
                .and_then(|o| o.timeline.as_ref())
                .map(|tl| tl.to_csv()),
            spans_json: observer.as_ref()
                .and_then(|o| o.spans.as_ref())
                .map(|sp| sp.to_chrome_json(&server_labels)),
            profile_json: settings.profile
                .then(|| prof.to_json().to_string()),
        }
    });

    let outcome = ScenarioOutcome {
        name: name.to_string(),
        seed,
        model: spec.model.to_string(),
        region: spec.region.name().to_string(),
        ci,
        requests: r.arrivals,
        completed: r.completed,
        generated_tokens: r.generated_tokens,
        events: r.events,
        fleet_gpus: plan.total_gpus(),
        fleet_servers,
        counts: plan.counts.clone(),
        plan_cost_hr: plan.cost_hr,
        plan_op_kg_per_hr: plan.op_kg_per_hr,
        plan_emb_kg_per_hr: plan.emb_kg_per_hr,
        ttft_p50_s: r.ttft.p50(),
        ttft_p90_s: r.ttft.p90(),
        ttft_p99_s: r.ttft.p99(),
        tpot_p50_s: r.tpot.p50(),
        tpot_p90_s: r.tpot.p90(),
        tpot_p99_s: r.tpot.p99(),
        throughput_tok_s: r.throughput_tok_s(),
        energy_j: r.energy_j,
        op_kg: r.op_kg,
        emb_kg: r.emb_kg,
        slo_attainment: r.slo_attainment,
        offline_deadline_attainment: r.offline_deadline_attainment,
        deferred: r.deferred_requests,
        truncated_prompts: r.truncated_prompts,
        provision_events: r.provision_events,
        decommission_events: r.decommission_events,
        peak_live_jobs: r.peak_live_jobs,
        provisioned_server_hours: r.provisioned_server_hours,
        extras,
    };
    (outcome, artifacts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeds_depend_on_name_not_order() {
        let a = scenario_seed(42, "online-latency");
        let b = scenario_seed(42, "offline-batch");
        assert_ne!(a, b);
        assert_eq!(a, scenario_seed(42, "online-latency"));
        assert_ne!(a, scenario_seed(43, "online-latency"));
    }

    #[test]
    fn jnum_maps_non_finite_to_null() {
        assert_eq!(jnum(1.5), Json::Num(1.5));
        assert_eq!(jnum(f64::NAN), Json::Null);
        assert_eq!(jnum(f64::INFINITY), Json::Null);
    }

    #[test]
    fn outcome_json_has_required_fields() {
        let sc = catalog::registry();
        let first = &sc[0];
        let out = first.run(scenario_seed(7, first.name()), 30.0);
        let j = out.to_json();
        for key in ["name", "carbon_kg", "op_kg", "emb_kg", "ttft_p50_s",
                    "ttft_p90_s", "tpot_p50_s", "slo_attainment",
                    "fleet_counts"] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed, j, "outcome JSON must round-trip");
    }
}
