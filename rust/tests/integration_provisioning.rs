//! Provisioning-event and rolling-horizon integration: draining servers
//! never admit new jobs, decommission fires only on empty servers, and
//! the autoscale scenarios beat their static peak-provisioned baselines
//! on total (op + amortized embodied) carbon without giving up SLO.

use ecoserve::models;
use ecoserve::scenarios::{catalog, run_sweep, SweepConfig};
use ecoserve::sim::{homogeneous_fleet, simulate_with, FifoBatch, FleetAction,
                    FleetEvent, Job, RouteCtx, RoutePolicy, Router, Server,
                    SimConfig};
use ecoserve::workload::{generate_trace, Arrivals, LengthDist, RequestClass};

/// JSQ clone that *asserts* every eligible server is admitting — the
/// routing-side proof that draining servers never see new work.
struct AssertAdmittingJsq;

impl RoutePolicy for AssertAdmittingJsq {
    fn name(&self) -> &'static str {
        "assert-admitting-jsq"
    }

    fn route(&self, _job: &Job, servers: &[Server], eligible: &[usize],
             _ctx: &RouteCtx) -> usize {
        for &i in eligible {
            assert!(servers[i].is_admitting(),
                    "server {i} offered for routing while {:?}",
                    servers[i].lifecycle());
        }
        *eligible.iter()
            .min_by_key(|&&i| servers[i].depth())
            .expect("no eligible servers")
    }
}

#[test]
fn draining_servers_are_never_offered_to_the_router() {
    let m = models::llm("llama-8b").unwrap();
    let tr = generate_trace(Arrivals::Poisson { rate: 6.0 },
                            LengthDist::ShareGpt, RequestClass::Online,
                            120.0, 31);
    let mut cfg = SimConfig::flat(homogeneous_fleet("A100-40", 4, m, 2048),
                                  Router::Jsq, 261.0, vec![0.005; 4]);
    // Drain two servers mid-trace, re-provision one later: every arrival
    // routed in between must only ever see admitting servers.
    cfg.fleet_plan.events = vec![
        FleetEvent { t: 30.0, server: 2, action: FleetAction::Drain },
        FleetEvent { t: 30.0, server: 3, action: FleetAction::Drain },
        FleetEvent { t: 80.0, server: 3, action: FleetAction::Provision },
    ];
    let r = simulate_with(m, &tr, &cfg, 0.5, 0.1, &AssertAdmittingJsq, &FifoBatch);
    assert_eq!(r.completed, tr.len(), "drained work was lost");
    assert!(r.decommission_events >= 1, "nothing decommissioned");
    // Re-provisioning counts only when the server had actually retired
    // (a cancelled drain reopens nothing).
    assert!(r.provision_events <= 1);
}

#[test]
fn decommission_only_fires_on_empty_servers() {
    let m = models::llm("llama-8b").unwrap();
    // Saturating load so the drained server is busy when the drain lands.
    let tr = generate_trace(Arrivals::Poisson { rate: 12.0 },
                            LengthDist::ShareGpt, RequestClass::Online,
                            90.0, 32);
    let mut cfg = SimConfig::flat(homogeneous_fleet("A100-40", 3, m, 2048),
                                  Router::Jsq, 261.0, vec![0.005; 3]);
    cfg.fleet_plan.events = vec![
        FleetEvent { t: 45.0, server: 2, action: FleetAction::Drain },
    ];
    let r = ecoserve::sim::simulate(m, &tr, &cfg, 0.5, 0.1);
    assert_eq!(r.completed, tr.len(), "in-flight batches must finish");
    assert_eq!(r.decommission_events, 1);
    let u = &r.per_server[2];
    // Retirement waited for the in-flight work: the provisioned interval
    // covers the whole busy time, extends past the drain decision, and
    // ends before the horizon (it did retire).
    assert!(u.busy_s <= u.provisioned_s + 1e-6,
            "busy {} outside provisioned {}", u.busy_s, u.provisioned_s);
    assert!(u.provisioned_s >= 45.0 - 1e-9,
            "retired before the drain decision: {}", u.provisioned_s);
    assert!(u.provisioned_s < r.sim_duration_s,
            "drained server never retired");
    // And the fleet-wide invariant: nobody is ever busy unprovisioned.
    for (i, u) in r.per_server.iter().enumerate() {
        assert!(u.busy_s <= u.provisioned_s + 1e-6, "server {i}");
    }
}

fn autoscale_outcome(name: &str, seed: u64, duration_s: f64)
    -> ecoserve::scenarios::ScenarioOutcome {
    let sel = catalog::by_names(&[name]).unwrap();
    let cfg = SweepConfig { threads: 1, seed, duration_s,
                            ..Default::default() };
    run_sweep(&sel, &cfg).outcomes.remove(0)
}

#[test]
fn autoscale_diurnal_beats_static_peak_on_total_carbon_at_equal_slo() {
    let o = autoscale_outcome("autoscale-diurnal", 7, 180.0);
    assert_eq!(o.completed, o.requests, "requests lost");
    assert!(o.decommission_events > 0, "fleet never scaled down");
    // The acceptance criterion: strictly lower total (operational +
    // amortized embodied) carbon than the static peak-provisioned
    // baseline, at unchanged online SLO attainment.
    let static_carbon = o.extras["carbon_kg_static"];
    assert!(o.carbon_kg() < static_carbon,
            "elastic {} !< static {}", o.carbon_kg(), static_carbon);
    // Embodied specifically amortizes over fewer provisioned hours.
    assert!(o.emb_kg < o.extras["emb_kg_static"],
            "elastic emb {} !< static emb {}",
            o.emb_kg, o.extras["emb_kg_static"]);
    assert!(o.provisioned_server_hours
                < o.extras["provisioned_server_hours_static"]);
    // "Unchanged" online SLO: the elastic fleet matches the static
    // baseline's attainment (within 1% for tie-breaking queueing noise)
    // and stays near-perfect in absolute terms.
    let static_slo = o.extras["slo_attainment_static"];
    assert!(o.slo_attainment >= static_slo - 0.01,
            "online SLO degraded: {} vs static {}",
            o.slo_attainment, static_slo);
    assert!(o.slo_attainment >= 0.95,
            "elastic SLO attainment collapsed: {}", o.slo_attainment);
}

#[test]
fn demand_surge_scales_up_for_the_spike_and_saves_carbon() {
    let o = autoscale_outcome("demand-surge", 7, 180.0);
    assert_eq!(o.completed, o.requests, "requests lost");
    // Quiet → surge → quiet forces both directions of elasticity.
    assert!(o.decommission_events > 0, "never drained the surplus");
    assert!(o.provision_events > 0, "never re-provisioned for the surge");
    assert!(o.carbon_kg() < o.extras["carbon_kg_static"],
            "elastic {} !< static {}",
            o.carbon_kg(), o.extras["carbon_kg_static"]);
    let static_slo = o.extras["slo_attainment_static"];
    assert!(o.slo_attainment >= static_slo - 0.02,
            "online SLO collapsed: {} vs static {}",
            o.slo_attainment, static_slo);
}

#[test]
fn autoscale_is_deterministic_across_thread_counts_and_epochs_differ() {
    let sel = |n| catalog::by_names(&["autoscale-diurnal", "demand-surge"])
        .map(|s| {
            let cfg = SweepConfig { threads: n, seed: 5, duration_s: 120.0,
                                    ..Default::default() };
            run_sweep(&s, &cfg).to_json().to_string()
        })
        .unwrap();
    assert_eq!(sel(1), sel(4), "provisioning schedules must be thread-safe");
    // The --epoch override changes the schedule (and hence the outcome).
    let s = catalog::by_names(&["autoscale-diurnal"]).unwrap();
    let base = SweepConfig { threads: 1, seed: 5, duration_s: 120.0,
                             ..Default::default() };
    let coarse = SweepConfig { epoch_s: Some(60.0), ..base.clone() };
    let a = run_sweep(&s, &base).outcomes.remove(0);
    let s = catalog::by_names(&["autoscale-diurnal"]).unwrap();
    let b = run_sweep(&s, &coarse).outcomes.remove(0);
    assert!(a.provision_events + a.decommission_events
                != b.provision_events + b.decommission_events
            || (a.provisioned_server_hours - b.provisioned_server_hours).abs()
                > 1e-9,
            "--epoch had no observable effect");
}
